"""Asynchronous AMA (paper Eqs. 6-11): weighting scheme + ring buffer.

The ring buffer is validated against a NAIVE event-list simulator that
literally keeps every delayed update and applies Eqs. 9-11 at arrival.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import FLConfig
from repro.core import async_ama as aa


def test_gamma_matches_paper_formula():
    fl = FLConfig(staleness_b=0.6)
    for s in [1, 2, 5, 15]:
        want = 0.6 * (1.0 - 1.0 / (1.0 + np.exp(-s)))
        assert np.isclose(float(aa.gamma_unnorm(fl, s)), want, rtol=1e-6)
    # monotone: staler updates weigh less
    gs = [float(aa.gamma_unnorm(fl, s)) for s in range(1, 16)]
    assert all(a > b for a, b in zip(gs, gs[1:]))


@settings(deadline=None, max_examples=50)
@given(st.floats(0.05, 0.4), st.floats(0.0, 5e-3), st.integers(0, 300),
       st.lists(st.integers(1, 15), min_size=0, max_size=6),
       st.floats(0.2, 1.0))
def test_mixing_weights_partition_of_unity(alpha0, eta, t, stalenesses, b):
    """Eq. 7: alpha + beta + sum(gamma) == 1; Eq. 8: alpha + sum(gamma) ==
    alpha0 + eta*t; all weights >= 0; alpha dominates every gamma."""
    fl = FLConfig(alpha0=alpha0, eta=eta, staleness_b=b)
    alpha, beta, gammas = aa.mixing_weights(fl, t, stalenesses)
    A = min(alpha0 + eta * t, fl.alpha_cap)
    assert np.isclose(alpha + beta + sum(gammas), 1.0, atol=1e-6)
    assert np.isclose(alpha + sum(gammas), A, atol=1e-6)
    assert alpha >= 0 and beta >= 0 and all(g >= 0 for g in gammas)
    # paper: alpha^- = 1 - sigmoid(1) >= gamma^- = b(1-sigmoid(s)) requires
    # b <= ~ (1-sig(1))/(1-sig(s)); with b<=1 and s>=1 it always holds
    for g in gammas:
        assert alpha >= g - 1e-9


def _params(rng):
    return {"w": jnp.asarray(rng.randn(4, 3), jnp.float32)}


def test_ring_buffer_vs_event_list():
    """Drive 12 rounds with random delays through (a) the ring buffer and
    (b) a literal event-list simulation; the aggregated models must match."""
    rng = np.random.RandomState(0)
    fl = FLConfig(alpha0=0.1, eta=2.5e-3, staleness_b=0.6, max_delay=4,
                  clients_per_round=3)
    C = fl.clients_per_round
    prev_rb = _params(rng)
    prev_ev = jax.tree.map(jnp.copy, prev_rb)
    queue = aa.init_queue(fl, prev_rb)
    pending_events = []   # (arrival_t, sent_t, params)

    for t in range(12):
        client_params = {"w": jnp.asarray(rng.randn(C, 4, 3), jnp.float32)}
        sizes = jnp.asarray(rng.rand(C) + 0.5, jnp.float32)
        delayed = rng.rand(C) < 0.5
        delays = np.where(delayed, rng.randint(1, fl.max_delay + 1, C), 1)
        on_time = jnp.asarray(~delayed)

        # --- ring buffer path
        queue = aa.enqueue(fl, queue, t, client_params,
                           jnp.asarray(delayed), jnp.asarray(delays))
        prev_rb, queue = aa.async_ama_aggregate(
            fl, t, prev_rb, client_params, sizes, on_time, queue)

        # --- event list path
        for i in range(C):
            if delayed[i]:
                pending_events.append(
                    (t + int(delays[i]), t,
                     jax.tree.map(lambda x, i=i: x[i], client_params)))
        arrivals = [(n, p) for (at, n, p) in pending_events if at == t]
        pending_events = [(at, n, p) for (at, n, p) in pending_events
                          if at != t]
        stalenesses = [t - n for (n, _) in arrivals]
        alpha, beta, gammas = aa.mixing_weights(fl, t, stalenesses)
        w = np.asarray(sizes) * (~delayed)
        if w.sum() > 0:
            w = w / w.sum()
            agg = np.einsum("cij,c->ij", np.asarray(client_params["w"]), w)
        else:
            agg = np.asarray(prev_ev["w"])
        new = alpha * np.asarray(prev_ev["w"]) + beta * agg
        for g, (_, p) in zip(gammas, arrivals):
            new = new + g * np.asarray(p["w"])
        prev_ev = {"w": jnp.asarray(new)}

        np.testing.assert_allclose(np.asarray(prev_rb["w"]), new,
                                   rtol=2e-5, atol=2e-6,
                                   err_msg=f"round {t}")


def test_sync_limit_no_delays_equals_plain_ama():
    """With no delayed updates the async path must reduce to Eq. 5."""
    from repro.core.ama import ama_aggregate
    rng = np.random.RandomState(1)
    fl = FLConfig(alpha0=0.15, eta=1e-3, max_delay=5)
    prev = _params(rng)
    C = 4
    cp = {"w": jnp.asarray(rng.randn(C, 4, 3), jnp.float32)}
    sizes = jnp.ones((C,), jnp.float32)
    on_time = jnp.ones((C,), bool)
    queue = aa.init_queue(fl, prev)
    got, _ = aa.async_ama_aggregate(fl, 3, prev, cp, sizes, on_time, queue)
    want = ama_aggregate(fl.with_(max_delay=0) if hasattr(fl, "with_")
                         else fl, 3, prev, cp, sizes, on_time)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]),
                               rtol=1e-5)
