"""AMA (paper Eq. 5) unit tests + convex-combination properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import FLConfig
from repro.core.ama import (alpha_schedule, ama_aggregate, ama_mix,
                            fedavg_aggregate, normalize_weights)


def tiny_tree(rng, C=None):
    shape = lambda *s: jnp.asarray(rng.randn(*s), jnp.float32)
    if C is None:
        return {"a": shape(3, 4), "b": {"c": shape(5)}}
    return {"a": shape(C, 3, 4), "b": {"c": shape(C, 5)}}


def test_alpha_schedule_matches_paper():
    fl = FLConfig(alpha0=0.1, eta=2.5e-3)
    assert np.isclose(float(alpha_schedule(fl, 0)), 0.1)
    assert np.isclose(float(alpha_schedule(fl, 100)), 0.35)
    # capped
    assert float(alpha_schedule(fl, 10_000)) == pytest.approx(fl.alpha_cap)


def test_ama_aggregate_hand_computed():
    rng = np.random.RandomState(0)
    fl = FLConfig(alpha0=0.2, eta=0.0)
    prev = tiny_tree(rng)
    clients = tiny_tree(rng, C=3)
    sizes = jnp.asarray([1.0, 2.0, 1.0])
    out = ama_aggregate(fl, 0, prev, clients, sizes)
    w = np.array([0.25, 0.5, 0.25])
    for key in ("a",):
        want = 0.2 * np.asarray(prev[key]) + 0.8 * np.einsum(
            "c...,c->...", np.asarray(clients[key]), w)
        np.testing.assert_allclose(np.asarray(out[key]), want, rtol=1e-5)


def test_all_delayed_falls_back_to_prev():
    rng = np.random.RandomState(1)
    fl = FLConfig(alpha0=0.3, eta=0.0)
    prev = tiny_tree(rng)
    clients = tiny_tree(rng, C=2)
    on_time = jnp.zeros((2,), bool)
    out = ama_aggregate(fl, 0, prev, clients, jnp.ones((2,)), on_time)
    for k, v in jax.tree_util.tree_leaves_with_path(out):
        pass
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(prev["a"]),
                               rtol=1e-5)


def test_fedavg_drops_excluded_clients():
    rng = np.random.RandomState(2)
    prev = tiny_tree(rng)
    clients = tiny_tree(rng, C=3)
    keep = jnp.asarray([True, False, True])
    out = fedavg_aggregate(prev, clients, jnp.asarray([1.0, 5.0, 3.0]), keep)
    w = np.array([0.25, 0.0, 0.75])
    want = np.einsum("c...,c->...", np.asarray(clients["a"]), w)
    np.testing.assert_allclose(np.asarray(out["a"]), want, rtol=1e-5)


@settings(deadline=None, max_examples=50)
@given(st.floats(0.01, 0.5), st.floats(0.0, 0.01), st.integers(0, 400))
def test_alpha_beta_convex(alpha0, eta, t):
    fl = FLConfig(alpha0=alpha0, eta=eta)
    a = float(alpha_schedule(fl, t))
    assert 0.0 < a <= fl.alpha_cap + 1e-6
    assert 0.0 <= 1.0 - a < 1.0


@settings(deadline=None, max_examples=30)
@given(st.lists(st.floats(0.5, 100.0), min_size=1, max_size=8))
def test_normalized_weights_sum_to_one(sizes):
    w, tot = normalize_weights(jnp.asarray(sizes),
                               jnp.ones((len(sizes),), bool))
    assert np.isclose(float(jnp.sum(w)), 1.0, atol=1e-5)


def test_ama_mix_kernel_path_matches_jnp():
    rng = np.random.RandomState(3)
    prev = tiny_tree(rng)
    agg = tiny_tree(rng)
    a = jnp.float32(0.37)
    base = ama_mix(prev, agg, a, use_kernel=False)
    kern = ama_mix(prev, agg, a, use_kernel=True)
    for b, k in zip(jax.tree.leaves(base), jax.tree.leaves(kern)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(k), rtol=1e-5)
