"""fedlint (repro.analysis) — fixture per rule: one that FIRES and one
clean near-miss, plus layer-2 checks against the real engine lowering
and the CLI gate contract CI relies on."""
import json
import os
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_rules as jr
from repro.analysis import run_paths
from repro.analysis.ast_rules import run_file

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _ast(src, path="fixture/mod.py", select=None):
    """Unsuppressed findings of the AST layer over a fixture source."""
    fs = run_file(path, textwrap.dedent(src), select)
    return [f for f in fs if not f.suppressed]


# ----------------------------------------------------------- FED101 --

_DONATE_FIRE = """
    import jax
    f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    def use(buf):
        out = f(buf)
        return out + buf
"""

_DONATE_CLEAN = """
    import jax
    f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    def use(buf):
        buf = f(buf)
        return buf + 1
"""


def test_fed101_use_after_donate_fires():
    fs = _ast(_DONATE_FIRE, select={"FED101"})
    assert [f.rule for f in fs] == ["FED101"]
    assert "'buf'" in fs[0].message and "line 5" in fs[0].message


def test_fed101_same_statement_reassign_is_clean():
    assert _ast(_DONATE_CLEAN, select={"FED101"}) == []


def test_fed101_compound_and_nested_defs_are_not_misattributed():
    # regression: the serving engine's while-loop prefill and nested
    # admit_wave closures both reassign the donated buffer in-statement
    src = """
        import jax
        class E:
            def __init__(self):
                self.pf = jax.jit(lambda p, c: (p, c), donate_argnums=(1,))
            def prefill(self, cache, n):
                for _ in range(n):
                    logits, cache = self.pf(0, cache)
                jax.block_until_ready(cache)
                return logits, cache
            def run(self, kv):
                def wave(kv):
                    kv = self.pf(0, kv)[1]
                    return kv
                return wave(kv) + wave(kv)
    """
    assert _ast(src, select={"FED101"}) == []


def test_fed101_donation_inside_loop_read_later_in_loop_fires():
    src = """
        import jax
        f = jax.jit(lambda x: x, donate_argnums=(0,))
        def use(buf, n):
            for _ in range(n):
                out = f(buf)
                print(buf)
    """
    fs = _ast(src, select={"FED101"})
    assert [f.rule for f in fs] == ["FED101"]


# ----------------------------------------------------------- FED102 --

_NONDET = """
    import jax
    import numpy as np
    @jax.jit
    def step(x):
        return x * np.random.rand()
"""


def test_fed102_host_rng_in_traced_code_fires():
    fs = _ast(_NONDET, select={"FED102"})
    assert [f.rule for f in fs] == ["FED102"]
    assert "np.random.rand" in fs[0].message


def test_fed102_host_side_rng_is_clean():
    src = """
        import numpy as np
        def host_plan():
            return np.random.rand()
    """
    assert _ast(src, select={"FED102"}) == []


def test_fed102_env_host_plane_is_allowlisted():
    assert _ast(_NONDET, path="src/repro/env/base.py",
                select={"FED102"}) == []


# ----------------------------------------------------------- FED103 --

def test_fed103_closure_mutation_in_scan_body_fires():
    src = """
        import jax
        acc = []
        def loop(c, xs):
            def body(c, x):
                acc.append(x)
                return c, x
            return jax.lax.scan(body, c, xs)
    """
    fs = _ast(src, select={"FED103"})
    assert [f.rule for f in fs] == ["FED103"]
    assert "acc.append" in fs[0].message


def test_fed103_local_mutation_in_scan_body_is_clean():
    src = """
        import jax
        def loop(c, xs):
            def body(c, x):
                parts = []
                parts.append(x)
                return c, sum(parts)
            return jax.lax.scan(body, c, xs)
    """
    assert _ast(src, select={"FED103"}) == []


# ----------------------------------------------------------- FED104 --

def test_fed104_print_in_pallas_kernel_fires():
    src = """
        import jax.experimental.pallas as pl
        def kernel(x_ref, o_ref):
            print("traced once")
            o_ref[...] = x_ref[...]
        def call(x):
            return pl.pallas_call(kernel, out_shape=x)(x)
    """
    fs = _ast(src, select={"FED104"})
    assert [f.rule for f in fs] == ["FED104"]
    assert "'print'" in fs[0].message


def test_fed104_ref_store_from_nested_loop_body_is_clean():
    # regression: rwkv6's fori step writes the enclosing kernel's output
    # ref — the kernel write idiom, not a closure mutation
    src = """
        import jax
        import jax.experimental.pallas as pl
        def kernel(x_ref, o_ref):
            def step(t, acc):
                o_ref[t] = acc
                return acc + x_ref[t]
            jax.lax.fori_loop(0, 4, step, 0.0)
        def call(x):
            return pl.pallas_call(kernel, out_shape=x)(x)
    """
    assert _ast(src, select={"FED103", "FED104"}) == []


# ----------------------------------------------------- FED105/FED106 --

def test_fed105_bare_except_fires_and_typed_is_clean():
    assert [f.rule for f in _ast("try:\n    pass\nexcept:\n    pass\n",
                                 select={"FED105"})] == ["FED105"]
    assert _ast("try:\n    pass\nexcept ValueError:\n    raise\n",
                select={"FED105"}) == []


def test_fed106_swallow_in_checkpoint_path_fires():
    src = "try:\n    pass\nexcept OSError:\n    pass\n"
    fs = _ast(src, path="src/repro/checkpoint/io.py", select={"FED106"})
    assert [f.rule for f in fs] == ["FED106"]
    # same code outside the checkpoint/prefetcher scope: out of scope
    assert _ast(src, path="src/repro/core/round.py",
                select={"FED106"}) == []


def test_fed106_handled_exception_is_clean():
    src = ("try:\n    pass\nexcept OSError as e:\n"
           "    raise RuntimeError('ckpt') from e\n")
    assert _ast(src, path="src/repro/checkpoint/io.py",
                select={"FED106"}) == []


# ------------------------------------------------- FED100/suppression --

def test_suppression_without_justification_emits_fed100():
    src = "try:\n    pass\nexcept:  # fedlint: disable=FED105\n    pass\n"
    fs = run_file("fixture/mod.py", src, None)
    assert [f.rule for f in fs if not f.suppressed] == ["FED100"]
    supp = [f for f in fs if f.suppressed]
    assert [f.rule for f in supp] == ["FED105"]


def test_justified_suppression_is_silent():
    src = ("try:\n    pass\n"
           "except:  # fedlint: disable=FED105 — fixture: wants everything\n"
           "    pass\n")
    fs = run_file("fixture/mod.py", src, None)
    assert [f.rule for f in fs if not f.suppressed] == []
    assert fs[0].justification == "fixture: wants everything"


def test_standalone_suppression_governs_next_line():
    src = ("try:\n    pass\n"
           "# fedlint: disable=FED105 — fixture: next-line form\n"
           "except:\n    pass\n")
    fs = run_file("fixture/mod.py", src, None)
    assert [f.rule for f in fs if not f.suppressed] == []


# ------------------------------------------------------- layer 2 (jaxpr) --

def test_fed201_real_chunkrunner_lowering_aliases_the_carry():
    """The acceptance check: the loop ChunkRunner actually jits must
    alias every donated params leaf in its lowering."""
    from repro.exec.engine import ChunkRunner
    fl = jr._tiny_fl(algorithm="ama")
    h = jr.TraceHarness(fl)
    runner = ChunkRunner(h.model, fl, h.strategy)
    txt = runner._train_loop().lower(*h.loop_args()).as_text()
    n_params = len(jax.tree.leaves(h.state["params"]))
    assert txt.count("tf.aliasing_output") >= n_params
    # and the rule agrees
    assert jr.check_donation_aliasing([("ama", fl)]) == []


def test_fed201_fires_when_donation_is_dropped():
    fl = jr._tiny_fl(algorithm="ama")
    fs = jr.check_donation_aliasing([("ama", fl)], donate=False)
    assert [f.rule for f in fs] == ["FED201"]
    assert "aliases 0 buffers" in fs[0].message


def test_fed202_debug_print_in_scan_fires_clean_scan_passes():
    def dirty(c, x):
        jax.debug.print("c={c}", c=c)
        return c + x, x

    def clean(c, x):
        return c + x, x

    mk = lambda body: jax.make_jaxpr(
        lambda c, xs: jax.lax.scan(body, c, xs))(0.0, jnp.zeros(3))
    fs = jr.check_scan_effects([("fx", None)],
                               jaxpr_fn=lambda l, f: mk(dirty))
    assert fs and all(f.rule == "FED202" for f in fs)
    assert jr.check_scan_effects([("fx", None)],
                                 jaxpr_fn=lambda l, f: mk(clean)) == []


def test_fed203_carry_shape_and_structure_drift_fire():
    fl = jr._tiny_fl(algorithm="ama")
    sds = jax.ShapeDtypeStruct
    in_s = {"a": sds((2,), jnp.float32)}
    grown = {"a": sds((3,), jnp.float32)}
    restructured = {"a": sds((2,), jnp.float32), "b": sds((), jnp.int32)}
    fire = jr.check_carry_stability(
        [("fx", fl)], step_fn=lambda h: (grown, in_s))
    assert [f.rule for f in fire] == ["FED203"]
    fire2 = jr.check_carry_stability(
        [("fx", fl)], step_fn=lambda h: (restructured, in_s))
    assert [f.rule for f in fire2] == ["FED203"]
    assert jr.check_carry_stability(
        [("fx", fl)], step_fn=lambda h: (in_s, in_s)) == []


def _fake_ref(**overrides):
    from repro.kernels import ref as real
    ns = types.SimpleNamespace(__name__="fake_ref")
    for n in dir(real):
        if not n.startswith("_"):
            setattr(ns, n, getattr(real, n))
    for k, v in overrides.items():
        if v is None:
            delattr(ns, k)
        else:
            setattr(ns, k, v)
    return ns


def test_fed204_real_kernels_have_matching_oracles():
    assert jr.check_kernel_oracles() == []


def test_fed204_catches_a_renamed_oracle():
    fs = jr.check_kernel_oracles(None, _fake_ref(server_mix_math=None))
    assert [f.rule for f in fs] == ["FED204"]
    assert "server_mix_flat" in fs[0].message


def test_fed204_catches_a_signature_mismatch():
    bad = _fake_ref(server_mix_math=lambda prev, stacked: None)
    fs = jr.check_kernel_oracles(None, bad)
    assert [f.rule for f in fs] == ["FED204"]
    assert "does not match" in fs[0].message


def test_config_matrix_covers_every_registered_strategy():
    from repro.core import strategies
    labels = {label.split("+")[0] for label, _ in jr.config_matrix()}
    classes = {strategies.get(n) for n in strategies.names()}
    assert len(jr.config_matrix()) >= len(classes)
    assert {"ama", "fedavg"} <= labels


# ----------------------------------------------------------- CLI gate --

def _cli(args, cwd=REPO):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-m", "repro.analysis", *args],
                          capture_output=True, text=True, env=env, cwd=cwd)


def test_cli_json_schema_and_exit_zero_on_clean_paths():
    p = _cli(["--json", os.path.join("src", "repro", "analysis")])
    assert p.returncode == 0, p.stdout + p.stderr
    doc = json.loads(p.stdout)
    assert doc["tool"] == "fedlint" and doc["schema_version"] == 1
    assert set(doc["summary"]) == {"total", "suppressed", "unsuppressed"}
    assert doc["summary"]["unsuppressed"] == 0


def test_cli_exits_nonzero_on_unsuppressed_findings(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("try:\n    pass\nexcept:\n    pass\n")
    p = _cli(["--json", str(bad)])
    assert p.returncode == 1, p.stdout + p.stderr
    doc = json.loads(p.stdout)
    assert any(f["rule"] == "FED105" for f in doc["findings"])
    assert doc["summary"]["unsuppressed"] == 1


def test_cli_list_rules_names_both_layers():
    p = _cli(["--list-rules"])
    assert p.returncode == 0
    lines = [ln for ln in p.stdout.splitlines() if ln.startswith("FED")]
    assert len(lines) >= 8
    assert any("jaxpr" in ln for ln in lines)


def test_repo_ast_layer_is_clean():
    """The tree the CI gate lints has zero unsuppressed AST findings."""
    paths = [os.path.join(REPO, p) for p in ("src", "benchmarks", "scripts")]
    fs = run_paths([p for p in paths if os.path.isdir(p)])
    assert [f for f in fs if not f.suppressed] == [], [
        f.render() for f in fs if not f.suppressed]
