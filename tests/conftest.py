import os
import sys

# Tests run on the single real CPU device (the 512-device env var is set
# ONLY inside launch/dryrun.py and the dry-run subprocess tests).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
