"""Environment subsystem: the batch/round bit-identity contract for
every registered environment, bernoulli == the seed scheduler
bit-for-bit, scenario registry integrity, trace save/load roundtrip,
and the fused scan engine consuming every environment unchanged."""
import jax
import numpy as np
import pytest

from repro import env as env_mod
from repro.configs.base import FLConfig
from repro.core.scheduler import HeterogeneitySchedule
from repro.env.scenarios import apply as apply_scenario
from repro.env.scenarios import names as scenario_names
from repro.env.trace import save_trace, synth_mobility_trace

# canonical (deduplicated) environment classes under their primary name
CANONICAL = sorted({cls.name for cls in map(env_mod.get, env_mod.names())})


def _fl(**kw):
    base = dict(num_clients=14, clients_per_round=5, p_limited=0.3,
                p_delay=0.4, max_delay=6, seed=3)
    base.update(kw)
    return FLConfig(**base)


# ---------------------------------------------------------------------------
# THE contract: batch row i == round(t0 + i), for every environment
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", CANONICAL)
@pytest.mark.parametrize("t0,n", [(0, 4), (9, 7)])
def test_batch_rows_bit_identical_to_sequential_rounds(name, t0, n):
    e = env_mod.get(name)(_fl(env=name))
    got = e.batch(t0, n)
    assert got["selected"].shape == (n, 5)
    for i in range(n):
        rs = e.round(t0 + i)
        np.testing.assert_array_equal(got["selected"][i], rs.selected)
        np.testing.assert_array_equal(got["limited"][i], rs.limited)
        np.testing.assert_array_equal(got["delayed"][i], rs.delayed)
        np.testing.assert_array_equal(got["delays"][i], rs.delays)
        np.testing.assert_array_equal(got["data_sizes"][i], rs.data_sizes)


@pytest.mark.parametrize("name", CANONICAL)
def test_batch_independent_of_chunking(name):
    """Round t is a pure function of (config, t) however the rounds are
    chunked or ordered — the killer case for stateful channels (the
    Gilbert-Elliott chain must memoize a trajectory that is pure in t).
    A FRESH instance queried out of order must agree too."""
    fl = _fl(env=name)
    e = env_mod.get(name)(fl)
    whole = e.batch(0, 12)
    split = {k: np.concatenate([e.batch(0, 5)[k], e.batch(5, 7)[k]])
             for k in whole}
    for k in whole:
        np.testing.assert_array_equal(whole[k], split[k])
    fresh = env_mod.get(name)(fl)
    rs = fresh.round(11)  # first query, deep into the run
    np.testing.assert_array_equal(whole["delays"][11], rs.delays)
    np.testing.assert_array_equal(whole["selected"][11], rs.selected)


@pytest.mark.parametrize("name", CANONICAL)
def test_schedule_invariants(name):
    """Delays live in [1, max_delay], are 1 where on time; selected are
    valid client ids; limited matches the fixed p_limited subset size
    at the population level."""
    fl = _fl(env=name)
    e = env_mod.get(name)(fl)
    sb = e.batch(0, 20)
    assert sb["selected"].min() >= 0
    assert sb["selected"].max() < fl.num_clients
    assert sb["delays"].min() >= 1
    assert sb["delays"].max() <= fl.max_delay
    np.testing.assert_array_equal(sb["delays"][~sb["delayed"]], 1)
    assert sb["data_sizes"].dtype == np.float32


@pytest.mark.parametrize("name", CANONICAL)
def test_zero_max_delay_disables_async_path(name):
    e = env_mod.get(name)(_fl(env=name, max_delay=0))
    sb = e.batch(0, 6)
    assert not sb["delayed"].any()
    np.testing.assert_array_equal(sb["delays"], np.ones((6, 5), np.int32))


# ---------------------------------------------------------------------------
# bernoulli == the seed HeterogeneitySchedule, bit-for-bit
# ---------------------------------------------------------------------------
def _seed_reference_round(fl, t, limited_set):
    """The seed repo's HeterogeneitySchedule.round, inlined verbatim as
    the frozen historical reference."""
    rng = np.random.RandomState(fl.seed * 1_000_003 + t)
    sel = rng.choice(fl.num_clients, size=fl.clients_per_round,
                     replace=False).astype(np.int32)
    limited = np.array([i in limited_set for i in sel])
    if fl.max_delay > 0 and fl.p_delay > 0:
        delayed = rng.rand(fl.clients_per_round) < fl.p_delay
        delays = rng.randint(1, fl.max_delay + 1,
                             size=fl.clients_per_round).astype(np.int32)
    else:
        delayed = np.zeros(fl.clients_per_round, bool)
        delays = np.ones(fl.clients_per_round, np.int32)
    delays = np.where(delayed, delays, 1).astype(np.int32)
    return sel, limited, delayed, delays


@pytest.mark.parametrize("p_delay,max_delay", [(0.0, 0), (0.4, 5)])
def test_bernoulli_env_bit_identical_to_seed_scheduler(p_delay, max_delay):
    fl = _fl(p_delay=p_delay, max_delay=max_delay)
    e = env_mod.get("bernoulli")(fl)
    rng = np.random.RandomState(fl.seed)
    k = int(round(fl.p_limited * fl.num_clients))
    limited_set = set(rng.choice(fl.num_clients, size=k,
                                 replace=False).tolist())
    assert e.devices.limited_set == limited_set
    for t in [0, 1, 17, 123]:
        rs = e.round(t)
        sel, lim, dly, d = _seed_reference_round(fl, t, limited_set)
        np.testing.assert_array_equal(rs.selected, sel)
        np.testing.assert_array_equal(rs.limited, lim)
        np.testing.assert_array_equal(rs.delayed, dly)
        np.testing.assert_array_equal(rs.delays, d)


def test_heterogeneity_schedule_wrapper_delegates_to_bernoulli_env():
    fl = _fl()
    hs = HeterogeneitySchedule(fl)
    e = env_mod.get("bernoulli")(fl)
    assert hs.limited_set == e.devices.limited_set
    got, want = hs.batch(2, 5), e.batch(2, 5)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


# ---------------------------------------------------------------------------
# components
# ---------------------------------------------------------------------------
def test_device_profile_tiers_and_step_budget():
    fl = _fl(fedprox_partial=0.5)
    e = env_mod.resolve(fl)
    sel = np.arange(fl.num_clients, dtype=np.int32)
    lim = e.devices.limited(sel)
    assert lim.sum() == int(round(fl.p_limited * fl.num_clients))
    np.testing.assert_array_equal(e.devices.tier(sel), np.where(lim, 0, 1))
    budget = e.devices.step_budget(8, sel)
    np.testing.assert_array_equal(budget[~lim], 8)
    np.testing.assert_array_equal(budget[lim], 4)


def test_data_sizes_flow_through_schedule():
    fl = _fl()
    sizes = np.arange(100, 100 + fl.num_clients, dtype=np.float32)
    e = env_mod.resolve(fl, data_sizes=sizes)
    rs = e.round(0)
    np.testing.assert_array_equal(rs.data_sizes, sizes[rs.selected])


def test_gilbert_elliott_is_bursty():
    """Bad-state delays must be temporally correlated: the chance a
    delayed round is followed by another delayed round for the same
    client exceeds the marginal delay rate."""
    fl = FLConfig(num_clients=4, clients_per_round=4, env="gilbert_elliott",
                  max_delay=10, ge_p_gb=0.1, ge_p_bg=0.2, seed=0)
    e = env_mod.resolve(fl)
    sb = e.batch(0, 400)
    order = np.argsort(sb["selected"], axis=1)
    by_client = np.take_along_axis(sb["delayed"], order, axis=1)  # (T, K)
    marginal = by_client.mean()
    pairs = by_client[:-1] & by_client[1:]
    cond = pairs.sum() / max(by_client[:-1].sum(), 1)
    assert cond > marginal + 0.05, (cond, marginal)


# ---------------------------------------------------------------------------
# trace: save/load roundtrip + synthetic mobility
# ---------------------------------------------------------------------------
def test_trace_roundtrip_replays_any_environment(tmp_path):
    fl = _fl(env="gilbert_elliott")
    recorded = env_mod.resolve(fl).batch(0, 9)
    path = str(tmp_path / "ge_trace.npz")
    save_trace(path, recorded)
    replay = env_mod.resolve(fl.with_(env="trace", trace_path=path))
    got = replay.batch(0, 9)
    for k in ("selected", "limited", "delayed", "delays"):
        np.testing.assert_array_equal(got[k], recorded[k])
    # the trace loops modulo its length
    rs = replay.round(9)
    np.testing.assert_array_equal(rs.selected, recorded["selected"][0])


def test_trace_rejects_delays_beyond_config_cap(tmp_path):
    """Replaying a trace recorded under a larger max_delay would wrap
    the async ring buffer — the load must fail loudly."""
    fl = _fl(env="gilbert_elliott", max_delay=15)
    path = str(tmp_path / "deep.npz")
    save_trace(path, env_mod.resolve(fl).batch(0, 40))
    with pytest.raises(AssertionError, match="max_delay"):
        env_mod.resolve(fl.with_(env="trace", trace_path=path, max_delay=6))


def test_synth_mobility_trace_deterministic_and_valid():
    fl = _fl(env="trace", trace_path="")
    a = synth_mobility_trace(fl, rounds=30)
    b = synth_mobility_trace(fl, rounds=30)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert a["selected"].shape == (30, fl.clients_per_round)
    # availability is coverage-gated: selection actually varies over time
    assert len({tuple(r) for r in a["selected"].tolist()}) > 1


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------
def test_every_scenario_builds_and_resolves():
    for name in scenario_names():
        fl = apply_scenario(FLConfig(num_clients=10, clients_per_round=4),
                            name)
        e = env_mod.resolve(fl)
        rs = e.round(3)
        assert rs.selected.shape == (4,)
        assert rs.delays.min() >= 1


def test_paper_scenarios_match_fig3_settings():
    fl = apply_scenario(FLConfig(), "moderate-30")
    assert (fl.env, fl.p_delay, fl.max_delay) == ("bernoulli", 0.3, 10)
    fl = apply_scenario(FLConfig(), "severe-70")
    assert (fl.env, fl.p_delay, fl.max_delay) == ("bernoulli", 0.7, 10)


# ---------------------------------------------------------------------------
# the fused scan engine consumes every environment unchanged
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", CANONICAL)
def test_train_loop_runs_against_every_environment(name):
    import jax.numpy as jnp

    from repro.configs.registry import ARCHS
    from repro.core.round import as_scan_scheds, init_state, make_train_loop
    from repro.models.api import build_model

    C = 2
    fl = FLConfig(num_clients=C, clients_per_round=C, env=name,
                  p_delay=0.5, max_delay=4, lr=0.1, cohorts=C,
                  local_steps=1, algorithm="ama_fes")
    model = build_model(ARCHS["paper-cnn"])
    rng = np.random.RandomState(0)
    batch = {"image": jnp.asarray(rng.randn(C, 1, 2, 28, 28, 1),
                                  jnp.float32),
             "label": jnp.asarray(rng.randint(0, 10, (C, 1, 2)), jnp.int32)}
    scheds = as_scan_scheds(env_mod.resolve(fl).batch(0, 2))
    loop = make_train_loop(model, fl, donate=False)
    state = init_state(model, fl, jax.random.PRNGKey(0))
    out, metrics = loop(state, batch, scheds)
    assert np.isfinite(np.asarray(metrics["loss"])).all()
    assert int(out["t"]) == 2
