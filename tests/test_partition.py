"""Partitioner properties beyond the defaults: shard_partition must keep
its exact-cover and <=shards_per_client-classes-per-client guarantees
for ANY shard count, and dirichlet_partition's concentration parameter
must actually control skew (hypothesis-guarded like the other property
tests)."""
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.partition import dirichlet_partition, shard_partition


@settings(deadline=None, max_examples=25)
@given(num_clients=st.integers(4, 25), n=st.integers(150, 600),
       shards=st.integers(1, 4), seed=st.integers(0, 10))
def test_shard_partition_cover_and_class_budget(num_clients, n, shards, seed):
    """Exact cover always; <= shards_per_client classes per client in the
    feasible regime (enough slots for every class to get one)."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 10, n)
    n_classes = int(labels.max()) + 1
    parts = shard_partition(labels, num_clients, shards_per_client=shards,
                            seed=seed)
    assert len(parts) == num_clients
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert len(all_idx) == n
    assert len(set(all_idx.tolist())) == n          # exact cover, no dupes
    if num_clients * shards >= n_classes:           # feasible regime
        for idx in parts:
            assert len(np.unique(labels[idx])) <= shards


@settings(deadline=None, max_examples=15)
@given(num_clients=st.integers(2, 15), alpha=st.floats(0.1, 10.0),
       seed=st.integers(0, 5))
def test_dirichlet_partition_cover_any_alpha(num_clients, alpha, seed):
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 10, 400)
    parts = dirichlet_partition(labels, num_clients, alpha=alpha, seed=seed)
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert sorted(all_idx.tolist()) == list(range(400))


def test_dirichlet_alpha_controls_skew():
    """Sanity: small alpha -> concentrated (skewed) clients, large alpha
    -> near-uniform clients. Measured as the std of per-client sizes."""
    rng = np.random.RandomState(0)
    labels = rng.randint(0, 10, 2000)

    def size_std(alpha):
        parts = dirichlet_partition(labels, 10, alpha=alpha, seed=0)
        return np.std([len(p) for p in parts])

    assert size_std(0.1) > 2 * size_std(100.0)


def test_dirichlet_large_alpha_spreads_classes():
    rng = np.random.RandomState(1)
    labels = rng.randint(0, 10, 2000)
    parts = dirichlet_partition(labels, 8, alpha=100.0, seed=1)
    for idx in parts:
        assert len(np.unique(labels[idx])) == 10   # every client sees all
