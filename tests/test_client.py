"""Local training engine: FedProx gradient + partial work, the
stale-loss fix, and the partitioned mixed-cohort FES client plane
(partitioned vs masked equivalence net)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.client import (make_limited_local_train, make_local_train,
                               make_partitioned_local_train)
from repro.core.round import init_state
from repro.data.pipeline import partition_plan
from repro.exec.engine import ChunkRunner
from repro.models.api import build_model


def _setup(algorithm, **kw):
    cfg = ARCHS["paper-cnn"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    steps = 4
    batch = {"image": jnp.asarray(rng.randn(1, steps, 8, 28, 28, 1),
                                  jnp.float32),
             "label": jnp.asarray(rng.randint(0, 10, (1, steps, 8)),
                                  jnp.int32)}
    fl = FLConfig(algorithm=algorithm, lr=0.05, **kw)
    return model, params, batch, fl


def test_fedprox_proximal_pull():
    """With a huge rho the proximal term dominates: params stay closer to
    the global model than plain SGD."""
    model, params, batch, _ = _setup("fedprox", fedprox_rho=0.0)
    lt0 = jax.jit(make_local_train(model, FLConfig(
        algorithm="fedprox", lr=0.05, fedprox_rho=0.0)))
    lt1 = jax.jit(make_local_train(model, FLConfig(
        algorithm="fedprox", lr=0.05, fedprox_rho=5.0)))
    out0, _ = lt0(params, batch, jnp.asarray([False]))
    out1, _ = lt1(params, batch, jnp.asarray([False]))

    def dist(a):
        return float(sum(jnp.sum((x[0] - y).astype(jnp.float32) ** 2)
                         for x, y in zip(jax.tree.leaves(a),
                                         jax.tree.leaves(params))))
    assert dist(out1) < dist(out0)


def test_fedprox_partial_work_fewer_steps():
    """A limited FedProx client runs fewer local steps -> ends closer to
    the initial model than an unlimited client on the same data."""
    model, params, batch, fl = _setup("fedprox", fedprox_partial=0.25,
                                      fedprox_rho=0.0)
    lt = jax.jit(make_local_train(model, fl))
    out_full, _ = lt(params, batch, jnp.asarray([False]))
    out_lim, _ = lt(params, batch, jnp.asarray([True]))

    def dist(a):
        return float(sum(jnp.sum((x[0] - y).astype(jnp.float32) ** 2)
                         for x, y in zip(jax.tree.leaves(a),
                                         jax.tree.leaves(params))))
    assert dist(out_lim) < dist(out_full)
    assert dist(out_lim) > 0  # but it did train


def test_loss_decreases_over_local_steps():
    model, params, batch, fl = _setup("ama_fes")
    lt = jax.jit(make_local_train(model, fl))
    out, loss = lt(params, batch, jnp.asarray([False]))
    big_batch = {k: jnp.concatenate([v] * 4, axis=1) for k, v in batch.items()}
    out2, loss2 = lt(params, big_batch, jnp.asarray([False]))
    assert float(loss2[0]) < float(loss[0]) + 0.1  # more steps, no blow-up
    assert np.isfinite(float(loss2[0]))


def test_fedprox_limited_loss_excludes_stale_steps():
    """Stale-loss regression: a fedprox_partial=0.5 limited cohort stops
    updating after 2 of 4 steps but the scan keeps evaluating the loss at
    the FROZEN params — the reported mean must cover the 2 ACTIVE steps
    only (hand-rolled truncated scan), not average the stale tail in."""
    model, params, batch, fl = _setup("fedprox", fedprox_partial=0.5,
                                      fedprox_rho=0.0)
    lt = jax.jit(make_local_train(model, fl))
    _, loss = lt(params, batch, jnp.asarray([True]))

    grad_fn = jax.value_and_grad(model.loss)
    p, losses = params, []
    for s in range(4):
        mb = jax.tree.map(lambda x: x[0, s], batch)
        l, g = grad_fn(p, mb)
        losses.append(float(l))
        if s < 2:                               # the active steps
            p = jax.tree.map(
                lambda pi, gi: (pi.astype(jnp.float32)
                                - fl.lr * gi.astype(jnp.float32)
                                ).astype(pi.dtype), p, g)
    np.testing.assert_allclose(float(loss[0]), np.mean(losses[:2]),
                               rtol=1e-6)
    # the pre-fix value (all 4 losses, 2 of them at frozen params) is a
    # DIFFERENT number — the bias this fix removes
    assert abs(float(loss[0]) - np.mean(losses)) > 1e-6


# ---------------------------------------------------------------------------
# partitioned mixed-cohort client plane (fl.client_plane = "partitioned")
# ---------------------------------------------------------------------------

def _mixed_world(C=5, steps=3, b=8, seed=0):
    cfg = ARCHS["paper-cnn"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(seed)
    batch = {"image": jnp.asarray(rng.randn(C, steps, b, 28, 28, 1),
                                  jnp.float32),
             "label": jnp.asarray(rng.randint(0, 10, (C, steps, b)),
                                  jnp.int32)}
    return model, params, batch


def _part_sched(limited: np.ndarray) -> dict:
    plan = partition_plan(np.asarray(limited)[None])
    return {"limited": jnp.asarray(limited),
            **{k: jnp.asarray(v[0]) for k, v in plan.items()}}


@pytest.mark.parametrize("algorithm,kw", [
    ("ama_fes", {}),
    ("fedprox", dict(fedprox_partial=0.5, fedprox_rho=0.01)),
    ("fedavg", {}),
    ("fedopt", {}),
])
def test_partitioned_matches_masked_per_cohort(algorithm, kw):
    """The equivalence net: for every strategy the partitioned plane's
    per-cohort params/losses agree with the masked reference — EXACTLY
    for unlimited cohorts (they run the identical program, just
    gathered/scattered) and to fp tolerance for limited ones (the
    classifier-only program contracts the same math without the body
    backward)."""
    model, params, batch = _mixed_world()
    limited = np.array([True, False, True, False, False])
    fl = FLConfig(algorithm=algorithm, lr=0.05, **kw)
    m_params, m_loss = jax.jit(make_local_train(model, fl))(
        params, batch, jnp.asarray(limited))
    p_params, p_loss = jax.jit(make_partitioned_local_train(model, fl))(
        params, batch, _part_sched(limited))
    for c in range(len(limited)):
        for a, b in zip(jax.tree.leaves(m_params),
                        jax.tree.leaves(p_params)):
            if limited[c]:
                np.testing.assert_allclose(
                    np.asarray(a[c], np.float32),
                    np.asarray(b[c], np.float32), rtol=1e-6, atol=1e-7)
            else:
                np.testing.assert_array_equal(np.asarray(a[c]),
                                              np.asarray(b[c]))
    np.testing.assert_allclose(np.asarray(m_loss), np.asarray(p_loss),
                               rtol=1e-6)


def test_partitioned_scatter_is_permutation_invariant():
    """Property: permuting the cohort slots (batch rows + limited flags)
    permutes the partitioned plane's outputs the same way — the
    gather/dispatch/scatter round-trip is slot-order oblivious."""
    model, params, batch = _mixed_world()
    limited = np.array([True, False, True, False, False])
    fl = FLConfig(algorithm="ama_fes", lr=0.05)
    lt = jax.jit(make_partitioned_local_train(model, fl))
    base_params, base_loss = lt(params, batch, _part_sched(limited))
    rng = np.random.RandomState(7)
    for _ in range(3):
        perm = rng.permutation(len(limited))
        pb = jax.tree.map(lambda x: x[perm], batch)
        perm_params, perm_loss = lt(params, pb, _part_sched(limited[perm]))
        for a, b in zip(jax.tree.leaves(base_params),
                        jax.tree.leaves(perm_params)):
            np.testing.assert_allclose(np.asarray(a[perm], np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(base_loss)[perm],
                                   np.asarray(perm_loss), rtol=1e-6)


def _flops(compiled) -> float:
    ca = compiled.cost_analysis()
    return float((ca if isinstance(ca, dict) else ca[0])["flops"])


def test_limited_program_drops_body_backward_flops():
    """Dry-run HLO cost analysis: the partitioned plane's limited
    program (classifier-only differentiation) must cost STRICTLY fewer
    FLOPs than the full program on the same batch — the body backward
    is gone, not merely masked."""
    model, params, batch = _mixed_world(C=1)
    fl = FLConfig(algorithm="ama_fes", lr=0.05)
    full = jax.jit(make_local_train(model, fl)).lower(
        params, batch, jnp.asarray([True])).compile()
    lim = jax.jit(make_limited_local_train(model, fl)).lower(
        params, batch).compile()
    f, l = _flops(full), _flops(lim)
    assert 0 < l < f, (l, f)


def test_partitioned_engine_matches_masked_scan_and_loop():
    """Mixed-cohort rounds through the execution engine: the partitioned
    plane's global params track the masked chunked-scan reference under
    BOTH the chunked scan and the scan-of-1 fallback, with per-round
    limited counts that vary (exercising the chunk-static overflow
    path: excess limited cohorts run the masked program)."""
    model, params, _ = _mixed_world()
    rng = np.random.RandomState(3)
    n, C, steps, b = 3, 4, 2, 4
    batch = {"image": rng.randn(n, C, steps, b, 28, 28, 1).astype(
                 np.float32),
             "label": rng.randint(0, 10, (n, C, steps, b)).astype(
                 np.int32)}
    limited = np.array([[1, 0, 1, 0], [0, 0, 0, 1], [1, 1, 0, 1]], bool)
    sb = {"limited": limited,
          "delayed": np.zeros((n, C), bool),
          "delays": np.ones((n, C), np.int32),
          "data_sizes": rng.rand(n, C).astype(np.float32) + 0.5}

    def run(plane, use_scan):
        fl = FLConfig(algorithm="fedprox", lr=0.05, fedprox_partial=0.5,
                      client_plane=plane)
        runner = ChunkRunner(model, fl, per_round_batch=True,
                             use_scan=use_scan, donate=False)
        state = init_state(model, fl, jax.random.PRNGKey(0))
        return runner.run_chunk(state, batch, dict(sb))

    ref_state, ref_metrics = run("masked", True)
    for use_scan in (True, False):
        st, m = run("partitioned", use_scan)
        for a, b2 in zip(jax.tree.leaves(ref_state["params"]),
                         jax.tree.leaves(st["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b2, np.float32),
                                       rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(m["loss"], ref_metrics["loss"],
                                   rtol=1e-5)
