"""Local training engine: FedProx gradient + partial work."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.client import make_local_train
from repro.models.api import build_model


def _setup(algorithm, **kw):
    cfg = ARCHS["paper-cnn"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    steps = 4
    batch = {"image": jnp.asarray(rng.randn(1, steps, 8, 28, 28, 1),
                                  jnp.float32),
             "label": jnp.asarray(rng.randint(0, 10, (1, steps, 8)),
                                  jnp.int32)}
    fl = FLConfig(algorithm=algorithm, lr=0.05, **kw)
    return model, params, batch, fl


def test_fedprox_proximal_pull():
    """With a huge rho the proximal term dominates: params stay closer to
    the global model than plain SGD."""
    model, params, batch, _ = _setup("fedprox", fedprox_rho=0.0)
    lt0 = jax.jit(make_local_train(model, FLConfig(
        algorithm="fedprox", lr=0.05, fedprox_rho=0.0)))
    lt1 = jax.jit(make_local_train(model, FLConfig(
        algorithm="fedprox", lr=0.05, fedprox_rho=5.0)))
    out0, _ = lt0(params, batch, jnp.asarray([False]))
    out1, _ = lt1(params, batch, jnp.asarray([False]))

    def dist(a):
        return float(sum(jnp.sum((x[0] - y).astype(jnp.float32) ** 2)
                         for x, y in zip(jax.tree.leaves(a),
                                         jax.tree.leaves(params))))
    assert dist(out1) < dist(out0)


def test_fedprox_partial_work_fewer_steps():
    """A limited FedProx client runs fewer local steps -> ends closer to
    the initial model than an unlimited client on the same data."""
    model, params, batch, fl = _setup("fedprox", fedprox_partial=0.25,
                                      fedprox_rho=0.0)
    lt = jax.jit(make_local_train(model, fl))
    out_full, _ = lt(params, batch, jnp.asarray([False]))
    out_lim, _ = lt(params, batch, jnp.asarray([True]))

    def dist(a):
        return float(sum(jnp.sum((x[0] - y).astype(jnp.float32) ** 2)
                         for x, y in zip(jax.tree.leaves(a),
                                         jax.tree.leaves(params))))
    assert dist(out_lim) < dist(out_full)
    assert dist(out_lim) > 0  # but it did train


def test_loss_decreases_over_local_steps():
    model, params, batch, fl = _setup("ama_fes")
    lt = jax.jit(make_local_train(model, fl))
    out, loss = lt(params, batch, jnp.asarray([False]))
    big_batch = {k: jnp.concatenate([v] * 4, axis=1) for k, v in batch.items()}
    out2, loss2 = lt(params, big_batch, jnp.asarray([False]))
    assert float(loss2[0]) < float(loss[0]) + 0.1  # more steps, no blow-up
    assert np.isfinite(float(loss2[0]))
