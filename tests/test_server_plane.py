"""The fused server-plane kernel suite (repro.kernels.server_plane).

Three layers of nets:
  * kernel-body parity — every server-plane Pallas kernel (and the
    pre-existing ama_mix) against its jnp oracle in interpret mode on
    CPU: f32 AND bf16 inputs, non-multiple-of-block N (the padding
    path), K=1 edge case. Tolerances are 1-2 ulp: the op sequence is
    shared, only XLA's shape-dependent FMA contraction differs.
  * strategy routing — all five registered strategies produce the same
    update through every ``fl.server_plane`` impl ("fused" == "ref"
    bit-identical off-TPU; "interpret" and "legacy" allclose).
  * engine — the fused plane inside the real chunked-scan engine
    matches the per-round loop bit-identically (the main nets live in
    tests/test_engine.py; here the interpret-mode kernel rides the scan
    to prove the Pallas body composes with lax.scan + donation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import strategies
from repro.kernels import ref
from repro.kernels.ama_mix import ama_mix_flat
from repro.kernels.server_plane import (server_adam_flat, server_async_flat,
                                        server_mix_flat)

TOL = {jnp.float32: dict(rtol=2e-6, atol=2e-6),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _close(got, want, dtype):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), **TOL[dtype])


def _flat_world(rng, K, N, dtype, Q=5):
    return dict(
        prev=jnp.asarray(rng.randn(N), dtype),
        stacked=jnp.asarray(rng.randn(K, N), dtype),
        sizes=jnp.asarray(rng.rand(K) + 0.5, jnp.float32),
        keep=jnp.asarray((rng.rand(K) < 0.7).astype(np.float32)),
        coefs=jnp.asarray([0.1, 2.5e-3, 0.95, 7.0], jnp.float32),
        qsum=jnp.asarray(rng.randn(Q, N).astype(np.float32)),
        qgamma=jnp.asarray(rng.rand(Q), jnp.float32),
        delays=jnp.asarray(rng.randint(1, Q, K), jnp.int32),
        tq=jnp.asarray([7, 7 % Q], jnp.int32),
        hyp=jnp.asarray([0.1, 2.5e-3, 0.95, 0.6], jnp.float32),
        m=jnp.asarray(rng.randn(N).astype(np.float32)),
        v=jnp.abs(jnp.asarray(rng.randn(N).astype(np.float32))),
        scalars=jnp.asarray([0.9, 0.99, 0.1, 1e-3, 3.0], jnp.float32),
    )


# --------------------------------------------- kernel-body parity nets ----

@pytest.mark.parametrize("N,block", [(4096, 1024), (4096 + 17, 1024),
                                     (100, 1024)])  # padding / block > N
@pytest.mark.parametrize("K", [1, 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_server_mix_kernel_matches_oracle(N, block, K, dtype):
    w = _flat_world(np.random.RandomState(N + K), K, N, dtype)
    got = server_mix_flat(w["prev"], w["stacked"], w["sizes"], w["keep"],
                          w["coefs"], block=block, interpret=True)
    want = ref.server_mix_math(w["prev"], w["stacked"], w["sizes"],
                               w["keep"], w["coefs"])
    assert got.dtype == w["prev"].dtype
    _close(got, want, dtype)


@pytest.mark.parametrize("N,block", [(2048, 512), (2048 + 31, 512)])
@pytest.mark.parametrize("K", [1, 6])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_server_async_kernel_matches_oracle(N, block, K, dtype):
    w = _flat_world(np.random.RandomState(N + K), K, N, dtype)
    delayed = (np.random.RandomState(K).rand(K) < 0.6).astype(np.float32)
    got = server_async_flat(w["prev"], w["stacked"], w["qsum"], w["qgamma"],
                            w["sizes"], jnp.asarray(delayed), w["delays"],
                            w["tq"], w["hyp"], block=block, interpret=True)
    want = ref.server_async_math(w["prev"], w["stacked"], w["qsum"],
                                 w["qgamma"], w["sizes"],
                                 jnp.asarray(delayed), w["delays"],
                                 w["tq"], w["hyp"])
    assert got[0].dtype == w["prev"].dtype
    assert got[1].dtype == jnp.float32 and got[2].dtype == jnp.float32
    _close(got, want, dtype)


@pytest.mark.parametrize("N,block", [(2048, 512), (2048 + 31, 512)])
@pytest.mark.parametrize("K", [1, 6])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_server_adam_kernel_matches_oracle(N, block, K, dtype):
    w = _flat_world(np.random.RandomState(N + K), K, N, dtype)
    got = server_adam_flat(w["prev"], w["stacked"], w["m"], w["v"],
                           w["sizes"], w["keep"], w["scalars"],
                           block=block, interpret=True)
    want = ref.server_adam_math(w["prev"], w["stacked"], w["m"], w["v"],
                                w["sizes"], w["keep"], w["scalars"])
    assert got[0].dtype == w["prev"].dtype
    _close(got, want, dtype)


@pytest.mark.parametrize("N", [100, 4096 + 17])   # padding / block > N
@pytest.mark.parametrize("K", [1, 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ama_mix_kernel_dtype_parity(N, K, dtype):
    """The pre-existing fused mix keeps the same dtype/padding contract
    as the new suite (complements the sweep in test_kernels.py)."""
    rng = np.random.RandomState(N * K)
    prev = jnp.asarray(rng.randn(N), dtype)
    stacked = jnp.asarray(rng.randn(K, N), dtype)
    alpha = jnp.float32(0.35)
    wts = jnp.asarray(rng.rand(K), jnp.float32)
    got = ama_mix_flat(prev, stacked, alpha, wts, block=1024,
                       interpret=True)
    want = ref.ama_mix_ref(prev, stacked, alpha, wts)
    assert got.dtype == prev.dtype and got.shape == (N,)
    _close(got, want, dtype)


def test_mix_empty_round_falls_back_to_prev():
    """keep == 0 for everyone: the whole beta budget reverts to the
    previous model (no NaNs from the 0/0 weight normalisation)."""
    w = _flat_world(np.random.RandomState(0), 4, 1024, jnp.float32)
    keep = jnp.zeros(4, jnp.float32)
    got = server_mix_flat(w["prev"], w["stacked"], w["sizes"], keep,
                          w["coefs"], block=512, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w["prev"]))


# ------------------------------------------------- strategy routing nets ----

def _tree(rng, C=None):
    f = lambda *s: jnp.asarray(rng.randn(*s), jnp.float32)
    return ({"a": f(3, 4), "b": {"c": f(5)}} if C is None
            else {"a": f(C, 3, 4), "b": {"c": f(C, 5)}})


def _sched(rng, C, max_delay=0):
    delayed = rng.rand(C) < 0.4
    delays = np.where(delayed, rng.randint(1, max(max_delay, 1) + 1, C), 1)
    return {"limited": jnp.asarray(rng.rand(C) < 0.5),
            "delayed": jnp.asarray(delayed),
            "delays": jnp.asarray(delays.astype(np.int32)),
            "data_sizes": jnp.asarray(rng.rand(C) + 0.5, jnp.float32)}


@pytest.mark.parametrize("algo,md", [("ama", 0), ("ama_fes", 3),
                                     ("fedavg", 0), ("fedprox", 0),
                                     ("fedopt", 0)])
def test_every_strategy_consistent_across_impls(algo, md):
    """fused == ref bit-identically off-TPU (same dispatch); interpret
    (the real Pallas body) and legacy (the pre-fusion chain) allclose —
    params AND aux state (ring buffer, moments)."""
    rng = np.random.RandomState(42)
    base = dict(algorithm=algo, max_delay=md, p_delay=0.4 if md else 0.0)
    prev, cp = _tree(rng), _tree(rng, C=4)
    sched = _sched(rng, 4, max_delay=md)
    outs = {}
    for impl in ("fused", "ref", "interpret", "legacy"):
        s = strategies.resolve(FLConfig(server_plane=impl, **base))
        outs[impl] = s.fused_server_update(2, prev, cp, sched,
                                           s.init_state(prev))
    for g, w in zip(jax.tree.leaves(outs["fused"]),
                    jax.tree.leaves(outs["ref"])):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    for other in ("interpret", "legacy"):
        for g, w in zip(jax.tree.leaves(outs["fused"]),
                        jax.tree.leaves(outs[other])):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-6)


def test_base_strategy_fallback_routes_to_aggregate():
    """Out-of-tree strategies that only define aggregate() keep working
    through the fused_server_update entry point."""
    calls = []

    class Custom(strategies.ServerStrategy):
        name = "custom-test"

        def aggregate(self, t, prev, cp, sched, aux):
            calls.append(int(t))
            return prev, aux

    s = Custom(FLConfig())
    rng = np.random.RandomState(0)
    prev = _tree(rng)
    out, aux = s.fused_server_update(5, prev, _tree(rng, C=3),
                                     _sched(rng, 3), {})
    assert calls == [5] and out is prev and aux == {}


# ------------------------------------------------------- engine net ----

def test_interpret_kernel_rides_scan_and_matches_loop():
    """The Pallas kernel body (interpret mode) composes with the fused
    lax.scan engine: scan == per-round loop bit-identically, and the
    result matches the default fused dispatch to tight tolerance."""
    from repro.configs.registry import ARCHS
    from repro.core.simulation import FederatedSimulation
    from repro.data.partition import shard_partition
    from repro.data.pipeline import build_clients
    from repro.data.synth import make_image_classification
    from repro.models.api import build_model

    train, test = make_image_classification(n_train=160, n_test=40, seed=0)
    clients = build_clients(train, shard_partition(train["label"], 6,
                                                   seed=0))
    model = build_model(ARCHS["paper-cnn"])
    states = {}
    for impl, use_scan in [("interpret", True), ("interpret", False),
                           ("fused", True)]:
        fl = FLConfig(num_clients=6, clients_per_round=3, local_epochs=1,
                      local_batch_size=8, lr=0.1, algorithm="ama_fes",
                      max_delay=2, p_delay=0.4, seed=0,
                      server_plane=impl)
        sim = FederatedSimulation(model, fl, clients, test,
                                  use_scan=use_scan)
        sim.run(rounds=2, eval_every=2)
        states[(impl, use_scan)] = sim.state
    for g, w in zip(jax.tree.leaves(states[("interpret", True)]),
                    jax.tree.leaves(states[("interpret", False)])):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    for g, w in zip(jax.tree.leaves(states[("interpret", True)]),
                    jax.tree.leaves(states[("fused", True)])):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=1e-4, atol=1e-5)
