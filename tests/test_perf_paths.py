"""Regression tests for the §Perf-adopted code paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, reduced
from repro.configs.registry import ARCHS
from repro.core.round import init_state, make_round_step
from repro.kernels import ref
from repro.models import moe
from repro.models.api import build_model
from repro.models.attention import chunked_attention


def test_grouped_moe_matches_global_dispatch():
    """Blocked dispatch (H1-it1) == global dispatch at ample capacity."""
    cfg = reduced(ARCHS["mixtral-8x22b"]).with_(
        dtype="float32", capacity_factor=8.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 64, cfg.d_model),
                    jnp.float32)
    o1, _ = moe.moe_apply(p, cfg, x)
    o2, _ = moe.moe_apply(p, cfg.with_(moe_group_size=32), x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("window", [0, 48])
def test_blocked_chunked_attention_matches_ref(window):
    """H1-it3: q-block x kv-chunk skipping must not change the math."""
    rng = np.random.RandomState(0)
    B, S, H, hd = 2, 128, 2, 32
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.4
    k = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.4
    v = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    got = chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                            chunk=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_chunked_attention_unaligned_cross():
    """Non-self-attention path (whisper cross-attn): no skipping, exact."""
    rng = np.random.RandomState(1)
    B, Sq, Skv, H, hd = 1, 48, 80, 2, 16
    q = jnp.asarray(rng.randn(B, Sq, H, hd), jnp.float32) * 0.4
    k = jnp.asarray(rng.randn(B, Skv, H, hd), jnp.float32) * 0.4
    v = jnp.asarray(rng.randn(B, Skv, H, hd), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    kpos = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    got = chunked_attention(q, k, v, qpos, kpos, causal=False, chunk=32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
    p = jax.nn.softmax(s, -1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_fes_static_round_runs_and_freezes_body():
    """H3-it1: the fes_static round trains only the classifier."""
    cfg = reduced(ARCHS["minitron-8b"])
    model = build_model(cfg)
    fl = FLConfig(algorithm="ama_fes", fes_static=True, lr=0.05)
    state = init_state(model, fl, jax.random.PRNGKey(0))
    step = jax.jit(make_round_step(model, fl))
    batch = {"tokens": jnp.ones((2, 1, 2, 16), jnp.int32)}
    sched = {"limited": jnp.ones((2,), bool),
             "delayed": jnp.zeros((2,), bool),
             "delays": jnp.ones((2,), jnp.int32),
             "data_sizes": jnp.ones((2,), jnp.float32)}
    p0 = jax.tree.map(jnp.copy, state["params"])
    state, metrics = step(state, batch, sched)
    assert np.isfinite(float(metrics["loss"]))
    # body frozen up to the AMA mix with the (identical) prev body:
    np.testing.assert_array_equal(
        np.asarray(p0["embed"]["table"], np.float32),
        np.asarray(state["params"]["embed"]["table"], np.float32))
    assert not np.array_equal(
        np.asarray(p0["lm_head"]["w"], np.float32),
        np.asarray(state["params"]["lm_head"]["w"], np.float32))


def test_constrain_noop_without_mesh():
    from repro.sharding.ctx import constrain
    x = jnp.ones((4, 6))
    y = constrain(x, None, "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
