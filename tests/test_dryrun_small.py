"""Dry-run machinery on a small (2x4) fake mesh in a subprocess (the env
var must be set before jax initialises, so this cannot run in-process)."""
import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    import numpy as np
    from repro.configs.base import SHAPES, ShapeConfig, reduced, FLConfig
    from repro.configs.registry import get_arch
    from repro.launch import dryrun
    from repro.launch.mesh import fl_view, serve_view

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = ShapeConfig("t", 64, 8, "train")
    fl = FLConfig(cohorts=2, local_steps=2, algorithm="ama_fes")
    results = {}
    for arch in ["minitron-8b", "zamba2-1.2b"]:
        cfg = reduced(get_arch(arch)).with_(num_layers=3, fes_tail_layers=1)
        low = dryrun.train_lowering(cfg, shape, mesh, fl)
        comp = low.compile()
        rec = dryrun.analyse(low, comp)
        results[arch] = rec["hlo_flops"]
    sshape = ShapeConfig("d", 64, 8, "decode")
    cfg = reduced(get_arch("minitron-8b")).with_(num_layers=3,
                                                 fes_tail_layers=1)
    from repro.models.api import build_model, input_specs
    low = dryrun.decode_lowering(cfg, sshape, mesh)
    low.compile()
    results["decode_ok"] = 1
    print("RESULT " + json.dumps(results))
""")


def test_small_mesh_dryrun():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout
    res = json.loads(line[0][len("RESULT "):])
    assert res["decode_ok"] == 1
    assert res["minitron-8b"] > 0
