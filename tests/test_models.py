"""Per-architecture smoke tests (reduced same-family variants, CPU) +
decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHS, ASSIGNED, serving_config
from repro.models.api import build_model

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32, rng=None):
    rng = rng or np.random.RandomState(0)
    if cfg.family == "cnn":
        return {"image": jnp.asarray(rng.randn(B, 28, 28, 1), jnp.float32),
                "label": jnp.asarray(rng.randint(0, cfg.vocab_size, B))}
    batch = {"tokens": jnp.asarray(
        rng.randint(1, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_emb"] = jnp.asarray(
            rng.randn(B, cfg.num_patches, cfg.vision_dim),
            jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frame_emb"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED + ["paper-cnn"])
def test_smoke_forward_and_train_step(arch):
    """Instantiate the reduced family variant, run one forward and one
    SGD step: finite loss, correct logits shape, params actually move."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch_for(cfg)
    logits, _ = jax.jit(model.forward)(params, batch)
    if cfg.family == "cnn":
        assert logits.shape == (2, cfg.vocab_size)
    else:
        S_out = logits.shape[1]
        assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, g = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [a for a in ASSIGNED])
def test_smoke_decode_step(arch):
    cfg = reduced(serving_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    B, maxlen = 2, 64
    if cfg.family == "audio":
        fe = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        cache = model.init_decode_cache(params, fe, maxlen)
    else:
        cache = model.init_decode_cache(params, B, maxlen)
    tok = jnp.ones((B,), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    step = jax.jit(model.decode_step)
    for t in range(3):
        logits, cache = step(params, tok, pos + t, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["minitron-8b", "rwkv6-3b", "zamba2-1.2b",
                                  "phi3.5-moe-42b-a6.6b", "whisper-medium"])
def test_decode_matches_forward(arch):
    """Token-by-token decoding must reproduce the full-sequence forward
    logits (f32 configs, generous MoE capacity so no tokens drop)."""
    cfg = reduced(ARCHS[arch]).with_(dtype="float32", remat=False)
    if cfg.num_experts:
        cfg = cfg.with_(capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.RandomState(0)
    B, S = 1, 12
    batch = _batch_for(cfg, B=B, S=S, rng=rng)
    full_logits, _ = model.forward(params, batch)
    if cfg.family == "audio":
        cache = model.init_decode_cache(params, batch["frame_emb"], S)
    else:
        cache = model.init_decode_cache(params, B, S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, batch["tokens"][:, t],
                             jnp.full((B,), t, jnp.int32), cache)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)           # (B, S, V)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_vlm_prepends_patches():
    cfg = reduced(ARCHS["phi-3-vision-4.2b"])
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch_for(cfg, S=16)
    logits, _ = model.forward(params, batch)
    assert logits.shape[1] == 16 + cfg.num_patches


def test_sliding_window_limits_attention():
    """With window w, logits at position t don't depend on tokens
    earlier than t - w."""
    cfg = reduced(ARCHS["mixtral-8x22b"]).with_(
        dtype="float32", sliding_window=8, capacity_factor=8.0, remat=False)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.RandomState(0)
    t1 = rng.randint(1, cfg.vocab_size, (1, 32))
    t2 = t1.copy()
    t2[0, :4] = rng.randint(1, cfg.vocab_size, 4)   # differ far in the past
    l1, _ = model.forward(params, {"tokens": jnp.asarray(t1)})
    l2, _ = model.forward(params, {"tokens": jnp.asarray(t2)})
    # last position attends to [24..31] only -> unchanged (token inputs
    # at the last 8+1 positions identical)
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-4, atol=1e-5)
