"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode — executes the kernel body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ama_mix import ama_mix_flat
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import ama_mix_tree
from repro.kernels.rwkv6_scan import rwkv6_scan


@pytest.mark.parametrize("N", [100, 1024, 4096 + 17])
@pytest.mark.parametrize("K", [1, 4, 10])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ama_mix_sweep(N, K, dtype):
    rng = np.random.RandomState(N + K)
    prev = jnp.asarray(rng.randn(N), dtype)
    stacked = jnp.asarray(rng.randn(K, N), dtype)
    alpha = jnp.float32(rng.rand())
    w = jnp.asarray(rng.rand(K), jnp.float32)
    got = ama_mix_flat(prev, stacked, alpha, w, block=1024, interpret=True)
    want = ref.ama_mix_ref(prev, stacked, alpha, w)
    tol = 1e-3 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ama_mix_tree_matches_eq5():
    """Kernel tree-mix == alpha*prev + (1-alpha)*weighted avg (Eq. 5)."""
    rng = np.random.RandomState(0)
    prev = {"w": jnp.asarray(rng.randn(7, 9), jnp.float32),
            "b": jnp.asarray(rng.randn(13), jnp.float32)}
    K = 3
    stacked = {"w": jnp.asarray(rng.randn(K, 7, 9), jnp.float32),
               "b": jnp.asarray(rng.randn(K, 13), jnp.float32)}
    alpha = jnp.float32(0.25)
    wts = jnp.asarray([0.2, 0.3, 0.5], jnp.float32) * (1 - 0.25)
    got = ama_mix_tree(prev, stacked, alpha, wts, interpret=True)
    for kk in prev:
        want = 0.25 * np.asarray(prev[kk]) + np.einsum(
            "k...,k->...", np.asarray(stacked[kk]), np.asarray(wts))
        np.testing.assert_allclose(np.asarray(got[kk]), want, rtol=1e-4,
                                   atol=1e-5)


@pytest.mark.parametrize("S,block", [(128, 64), (256, 128), (384, 128)])
@pytest.mark.parametrize("window", [0, 96])
@pytest.mark.parametrize("hd", [64, 128])
def test_flash_attention_sweep(S, block, window, hd):
    if S % block:
        pytest.skip("block must divide S")
    rng = np.random.RandomState(S + window + hd)
    B, H = 2, 2
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=block, block_k=block, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    rng = np.random.RandomState(0)
    B, S, H, hd = 1, 128, 2, 64
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.bfloat16) * 0.3
    k = jnp.asarray(rng.randn(B, S, H, hd), jnp.bfloat16) * 0.3
    v = jnp.asarray(rng.randn(B, S, H, hd), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 128), (96, 32)])
@pytest.mark.parametrize("hd", [16, 64])
def test_rwkv6_scan_sweep(S, chunk, hd):
    rng = np.random.RandomState(S + hd)
    B, H = 2, 2
    r = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.5
    k = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.5
    v = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    w = jnp.asarray(rng.rand(B, S, H, hd) * 0.5 + 0.4, jnp.float32)
    u = jnp.asarray(rng.randn(H, hd) * 0.1, jnp.float32)
    s0 = jnp.asarray(rng.randn(B, H, hd, hd) * 0.1, jnp.float32)
    y, sf = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    y2, sf2 = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf2), rtol=1e-4,
                               atol=1e-5)


def test_rwkv6_kernel_state_carries_across_chunks():
    """Chunked kernel result must be invariant to the chunk size."""
    rng = np.random.RandomState(7)
    B, S, H, hd = 1, 64, 1, 16
    args = [jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.4
            for _ in range(3)]
    w = jnp.asarray(rng.rand(B, S, H, hd) * 0.4 + 0.5, jnp.float32)
    u = jnp.asarray(rng.randn(H, hd) * 0.1, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    y16, _ = rwkv6_scan(*args[:3], w, u, s0, chunk=16, interpret=True)
    y64, _ = rwkv6_scan(*args[:3], w, u, s0, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), rtol=1e-5,
                               atol=1e-6)
