"""The compressed communication plane (repro.comm + fused server kernels).

Five nets, mirroring the plane's layering:

  * codec units — registry/resolve contract, nominal wire fractions,
    exact payload byte accounting (topk < q8 < bf16 < dense);
  * kernel parity — the fused dequantize-accumulate Pallas bodies
    (``server_mix_delta_flat`` int8 AND bf16 payloads,
    ``server_mix_scatter_flat``) against their jnp oracles in interpret
    mode: padding path, K=1 edge;
  * fused == densify — ``server_mix_compressed_tree`` must equal
    reconstruct-then-dense-mix for every payload kind (the strategies'
    ``compressed_server_update`` is only a dispatch around this);
  * engine — scan == loop bit-identity WITH compression + error-feedback
    residual aux for all five strategies, resume-tail bit-identity with
    ``aux["comm"]`` in the checkpoint, and the ``comm_plane="none"``
    structural no-op (no comm aux, wire fraction 1, dense bytes);
  * telemetry/CI plumbing — compressed-wire round metrics, the
    bandwidth env consuming the wire fraction (compression raises
    on-time participation), and ``check_metrics.py --require-comm``.

Property-based versions of the codec bounds (hypothesis-gated, nightly)
live in tests/test_comm_properties.py.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro import env as env_mod
from repro.comm.plane import Q8Plane, TopKPlane, decode
from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.kernels import ref
from repro.kernels.server_plane import (server_mix_compressed_tree,
                                        server_mix_delta_flat,
                                        server_mix_scatter_flat,
                                        server_mix_tree)
from repro.models.api import build_model
from repro.obs.log import MetricsLogger

ROOT = os.path.join(os.path.dirname(__file__), "..")

TOL = dict(rtol=2e-6, atol=2e-6)


@pytest.fixture(scope="module")
def small_world():
    train, test = make_image_classification(n_train=240, n_test=60, seed=0)
    clients = build_clients(train, shard_partition(train["label"], 8, seed=0))
    model = build_model(ARCHS["paper-cnn"])
    return model, clients, test


def _fl(**kw):
    base = dict(num_clients=8, clients_per_round=4, local_epochs=1,
                local_batch_size=10, lr=0.1, p_limited=0.25, seed=0)
    base.update(kw)
    return FLConfig(**base)


def assert_states_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------- codec units ----

def test_registry_and_resolve_contract():
    assert {"bf16", "q8", "int8", "topk"} <= set(comm.names())
    assert comm.resolve(_fl()) is None                 # dense default
    assert comm.resolve(_fl(comm_plane="none")) is None
    assert isinstance(comm.resolve(_fl(comm_plane="q8")), Q8Plane)
    assert isinstance(comm.resolve(_fl(comm_plane="int8")), Q8Plane)
    assert isinstance(comm.resolve(_fl(comm_plane="topk")), TopKPlane)
    with pytest.raises(ValueError, match="unknown comm plane"):
        comm.resolve(_fl(comm_plane="zip"))
    with pytest.raises(ValueError, match="comm_topk_frac"):
        comm.resolve(_fl(comm_plane="topk", comm_topk_frac=0.0))


def test_nominal_wire_fractions():
    assert comm.wire_fraction(_fl()) == 1.0
    assert comm.wire_fraction(_fl(comm_plane="bf16")) == 0.5
    assert comm.wire_fraction(_fl(comm_plane="q8")) == 0.25
    assert comm.wire_fraction(
        _fl(comm_plane="topk", comm_topk_frac=0.05)) == pytest.approx(0.1)
    # value+index pairs stop paying off past frac = 1/2
    assert comm.wire_fraction(
        _fl(comm_plane="topk", comm_topk_frac=0.9)) == 1.0


def test_payload_bytes_ordering(small_world):
    model, _, _ = small_world
    params = model.init(jax.random.PRNGKey(0))
    dense = comm.dense_bytes(params)
    by = {p: comm.resolve(_fl(comm_plane=p, comm_topk_frac=0.01))
          .payload_bytes(params) for p in ("bf16", "q8", "topk")}
    assert by["topk"] < by["q8"] < by["bf16"] < dense
    assert by["bf16"] * 2 == dense                    # f32 model: exactly 2x
    # q8 = 1 byte/param + one f32 scale word per dtype group
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    assert n_params <= by["q8"] <= n_params + 4 * len(jax.tree.leaves(params))


def test_codec_roundtrip_and_error_feedback_algebra():
    """One compress() pass per plane on a toy tree: decode(payload) + new
    residual telescopes back to the exact dense error, and q8 honours
    its elementwise bound."""
    rng = np.random.RandomState(7)
    prev = {"w": jnp.asarray(rng.randn(13, 5), jnp.float32),
            "b": jnp.asarray(rng.randn(5), jnp.float32)}
    K = 3
    stacked = jax.tree.map(
        lambda p: p[None] + jnp.asarray(
            rng.randn(K, *p.shape) * 0.1, jnp.float32), prev)
    n = 13 * 5 + 5
    # dense flat delta in canonical leaf order (tree.leaves order)
    leaves_p = jax.tree.leaves(prev)
    leaves_s = jax.tree.leaves(stacked)
    d_dense = np.concatenate(
        [np.asarray(s.reshape(K, -1) - p.reshape(-1)[None])
         for p, s in zip(leaves_p, leaves_s)], axis=1)
    for name in ("bf16", "q8", "topk"):
        plane = comm.resolve(_fl(comm_plane=name, comm_topk_frac=0.1))
        res0 = plane.init_residual(prev, K)
        assert set(res0) == {"g0"} and res0["g0"].shape == (K, n)
        groups, res1 = plane.compress(0, prev, stacked, res0)
        assert len(groups) == 1
        dq = np.asarray(decode(groups[0][1], n))
        # EF telescoping: dq + residual == dense delta (float32 algebra)
        np.testing.assert_allclose(dq + np.asarray(res1["g0"]), d_dense,
                                   rtol=1e-5, atol=1e-6)
        if name == "q8":
            scale = np.asarray(groups[0][1]["scale"])
            assert np.all(np.abs(d_dense - dq) <= scale[:, None] * (1 + 1e-6))
        if name == "topk":
            kk = plane._kk(n)
            assert groups[0][1]["v"].shape == (K, kk)
            assert np.count_nonzero(dq, axis=1).max() <= kk
    # error feedback off: no residual state at all
    plane = comm.resolve(_fl(comm_plane="q8", comm_error_feedback=False))
    assert plane.init_residual(prev, K) == {}
    groups, res = plane.compress(0, prev, stacked, {})
    assert res == {} and len(groups) == 1


def test_q8_stochastic_rounding_pure_in_round_index():
    """Same (t, inputs) -> bit-identical payload; different t -> a
    different draw (the scan == resume determinism contract)."""
    rng = np.random.RandomState(0)
    prev = {"w": jnp.asarray(rng.randn(64), jnp.float32)}
    stacked = {"w": prev["w"][None] + jnp.asarray(
        rng.randn(2, 64) * 0.1, jnp.float32)}
    plane = comm.resolve(_fl(comm_plane="q8"))
    (g1,), _ = plane.compress(3, prev, stacked, {})
    (g2,), _ = plane.compress(3, prev, stacked, {})
    (g3,), _ = plane.compress(4, prev, stacked, {})
    np.testing.assert_array_equal(np.asarray(g1[1]["d"]),
                                  np.asarray(g2[1]["d"]))
    assert not np.array_equal(np.asarray(g1[1]["d"]),
                              np.asarray(g3[1]["d"]))


# -------------------------------------------------------- kernel parity ----

def _mix_world(rng, K, N):
    return dict(prev=jnp.asarray(rng.randn(N), jnp.float32),
                sizes=jnp.asarray(rng.rand(K) + 0.5, jnp.float32),
                keep=jnp.asarray((rng.rand(K) < 0.7).astype(np.float32)),
                coefs=jnp.asarray([0.1, 2.5e-3, 0.95, 7.0], jnp.float32))


@pytest.mark.parametrize("N,block", [(4096, 1024), (4096 + 17, 1024),
                                     (100, 1024)])  # padding / block > N
@pytest.mark.parametrize("K", [1, 7])
@pytest.mark.parametrize("qdtype", [jnp.int8, jnp.bfloat16])
def test_mix_delta_kernel_matches_oracle(N, block, K, qdtype):
    """Fused dequantize-accumulate: int8 and bf16 compressed rows upcast
    inside the kernel tile == the jnp oracle's math."""
    rng = np.random.RandomState(N + K)
    w = _mix_world(rng, K, N)
    if qdtype == jnp.int8:
        d = jnp.asarray(rng.randint(-127, 128, (K, N)), jnp.int8)
        rowscale = jnp.asarray(rng.rand(K) * 1e-2 + 1e-4, jnp.float32)
    else:
        d = jnp.asarray(rng.randn(K, N), jnp.bfloat16)
        rowscale = jnp.ones((K,), jnp.float32)
    got = server_mix_delta_flat(w["prev"], d, rowscale, w["sizes"],
                                w["keep"], w["coefs"], block=block,
                                interpret=True)
    want = ref.server_mix_delta_math(w["prev"], d, rowscale, w["sizes"],
                                     w["keep"], w["coefs"])
    assert got.dtype == w["prev"].dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("N,block", [(2048, 512), (2048 + 31, 512)])
@pytest.mark.parametrize("K", [1, 6])
def test_mix_scatter_kernel_matches_oracle(N, block, K):
    """Top-k scatter plane: every tile sees the full coordinate list and
    applies only in-tile positions — incl. positions landing in the
    padded tail tile."""
    rng = np.random.RandomState(N + K)
    w = _mix_world(rng, K, N)
    kk = 37
    idx = jnp.asarray(np.stack([rng.choice(N, kk, replace=False)
                                for _ in range(K)]), jnp.int32)
    vals = jnp.asarray(rng.randn(K, kk), jnp.float32)
    got = server_mix_scatter_flat(w["prev"], vals, idx, w["sizes"],
                                  w["keep"], w["coefs"], block=block,
                                  interpret=True)
    want = ref.server_mix_scatter_math(w["prev"], vals, idx, w["sizes"],
                                       w["keep"], w["coefs"])
    assert got.dtype == w["prev"].dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# ------------------------------------------------------ fused == densify ----

@pytest.mark.parametrize("plane_name", ["bf16", "q8", "topk"])
def test_compressed_tree_matches_reconstruct_then_dense_mix(small_world,
                                                            plane_name):
    """server_mix_compressed_tree(groups) == dense mix over the plane's
    own reconstruction — on both the oracle and the interpret kernel
    path. This is the invariant that makes the strategies' densify
    fallback and the fused hook interchangeable."""
    model, _, _ = small_world
    prev = model.init(jax.random.PRNGKey(3))
    K = 4
    rng = np.random.RandomState(11)
    stacked = jax.tree.map(
        lambda p: p[None] + jnp.asarray(
            rng.randn(K, *p.shape) * 0.05, p.dtype), prev)
    plane = comm.resolve(_fl(comm_plane=plane_name, comm_topk_frac=0.05))
    groups, _ = plane.compress(2, prev, stacked, {})
    sizes = jnp.asarray(rng.rand(K) + 0.5, jnp.float32)
    keep = jnp.asarray((rng.rand(K) < 0.75).astype(np.float32))
    coefs = jnp.asarray([0.1, 2.5e-3, 0.95, 5.0], jnp.float32)
    recon = plane.reconstruct(prev, groups)
    want = server_mix_tree(prev, recon, sizes, keep, coefs, impl="ref")
    for impl in ("ref", "interpret"):
        got = server_mix_compressed_tree(prev, groups, sizes, keep, coefs,
                                         impl=impl)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), **TOL)


# ---------------------------------------------------------------- engine ----

ENGINE_CASES = [("ama", "q8"), ("async_ama", "q8"), ("fedavg", "q8"),
                ("fedprox", "q8"), ("fedopt", "q8"),
                ("ama", "topk"), ("fedavg", "bf16")]


@pytest.mark.parametrize("algo,plane", ENGINE_CASES)
def test_chunked_scan_bit_identical_with_compression(small_world, algo,
                                                     plane):
    """All five strategies under q8 (fused mix family + densify
    fallbacks) and the other planes on a representative each: the
    chunked-scan engine == the per-round loop bit-identically, with the
    error-feedback residual riding aux["comm"]."""
    model, clients, test = small_world
    md = 3 if algo == "async_ama" else 0
    fl = _fl(algorithm=algo, comm_plane=plane, comm_topk_frac=0.05,
             max_delay=md, p_delay=0.4 if md else 0.0)
    sims = {s: FederatedSimulation(model, fl, clients, test, use_scan=s)
            for s in (True, False)}
    hists = {s: sim.run(rounds=3, eval_every=3) for s, sim in sims.items()}
    assert_states_identical(sims[True].state, sims[False].state)
    assert hists[True].train_loss == hists[False].train_loss
    assert hists[True].test_acc == hists[False].test_acc
    aux = sims[True].state["aux"]
    assert "comm" in aux
    res = aux["comm"]["g0"]
    assert res.shape[0] == fl.clients_per_round
    assert res.dtype == jnp.float32
    # every plane leaves a nonzero residual after a real round (for
    # bf16 it is the dropped low mantissa bits of the f32 deltas)
    assert float(jnp.max(jnp.abs(res))) > 0.0


def test_resume_tail_bit_identical_with_residual_aux(small_world, tmp_path):
    """The checkpoint carries aux["comm"]: save -> restore -> continue
    == uninterrupted, bit-identically, under q8 + error feedback (the
    residual AND the stochastic-rounding stream both replay)."""
    model, clients, test = small_world
    fl = _fl(algorithm="ama", comm_plane="q8")
    path = str(tmp_path / "state.npz")

    full = FederatedSimulation(model, fl, clients, test)
    hist_full = full.run(rounds=5, eval_every=2)

    part = FederatedSimulation(model, fl, clients, test)
    part.run(rounds=3, eval_every=2)
    part.save(path)

    cont = FederatedSimulation(model, fl, clients, test)
    cont.resume(path)
    assert cont.t == 3
    assert "comm" in cont.state["aux"]
    hist_cont = cont.run(rounds=2, eval_every=2)

    assert_states_identical(full.state, cont.state)
    assert hist_full.train_loss[3:] == hist_cont.train_loss
    assert hist_cont.test_acc == hist_full.test_acc[1:]


def test_none_plane_is_structurally_dense(small_world):
    """comm_plane="none" resolves to no plane at all: no aux["comm"],
    dense wire fraction/bytes, compression_ratio exactly 1.0 — the
    engine's pre-comm program, untouched. With comm_error_feedback off,
    compressed planes also carry no residual state."""
    model, clients, test = small_world
    sim = FederatedSimulation(model, _fl(), clients, test)
    sim.run(rounds=2, eval_every=2)
    assert "comm" not in sim.state["aux"]

    sim_nf = FederatedSimulation(
        model, _fl(comm_plane="q8", comm_error_feedback=False), clients,
        test)
    sim_nf.run(rounds=2, eval_every=2)
    assert "comm" not in sim_nf.state["aux"]


# ----------------------------------------------------- telemetry + env ----

def test_round_metrics_carry_compressed_wire_fields(small_world):
    """Extended round rows: bytes_on_wire_compressed charges the ACTUAL
    q8 payload (~4x less than dense) and compression_ratio is the
    static dense/compressed ratio; the dense plane reports exactly 1.0
    with compressed == bytes_on_wire."""
    model, clients, test = small_world
    rows = {}
    for plane in ("none", "q8"):
        fl = _fl(algorithm="ama", comm_plane=plane, extended_metrics=True)
        logger = MetricsLogger(None)
        FederatedSimulation(model, fl, clients, test,
                            logger=logger).run(rounds=2, eval_every=2)
        rows[plane] = [r for r in logger.rows if r["kind"] == "round"]
    params = model.init(jax.random.PRNGKey(0))
    dense = comm.dense_bytes(params)
    per_client = comm.resolve(
        _fl(comm_plane="q8")).payload_bytes(params)
    for r in rows["none"]:
        assert r["compression_ratio"] == 1.0
        assert r["bytes_on_wire_compressed"] == r["bytes_on_wire"]
    for r in rows["q8"]:
        assert r["compression_ratio"] == pytest.approx(
            dense / per_client, rel=1e-6)
        assert r["bytes_on_wire_compressed"] == pytest.approx(
            r["n_on_time"] * per_client)
        assert r["bytes_on_wire_compressed"] < r["bytes_on_wire"]


def test_bandwidth_env_consumes_wire_fraction():
    """The bandwidth env's deadline check prices the COMPRESSED upload:
    q8 strictly raises on-time participation over dense at a deadline
    that dense mostly misses (the paper's delay-tolerance-vs-compression
    effect), and the plane leaves the delay distribution's support
    unchanged."""
    on_time = {}
    for plane in ("none", "q8"):
        fl = _fl(comm_plane=plane, env="bandwidth", max_delay=5,
                 bw_upload_mbits=16.0, bw_mean_mbps=4.0, bw_sigma=0.8,
                 bw_deadline_s=1.0)
        sb = env_mod.resolve(fl).batch(0, 200)
        on_time[plane] = float(np.mean(~np.asarray(sb["delayed"], bool)))
    assert on_time["q8"] > on_time["none"]


def test_check_metrics_require_comm(tmp_path):
    """scripts/check_metrics.py --require-comm: exit 0 on rows with real
    compression, exit 1 when the wire fields are missing or the ratio
    never exceeds 1 (a plane that silently ships dense bytes); plain
    validation still accepts schema-2 files without the new fields."""
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    script = os.path.join(ROOT, "scripts", "check_metrics.py")

    def jsonl(name, rows):
        p = tmp_path / name
        p.write_text("".join(json.dumps(r) + "\n" for r in rows))
        return str(p)

    def rnd(t, **kw):
        return {"kind": "round", "t": t, "loss": 1.0, "n_on_time": 4,
                "bytes_on_wire": 800.0, **kw}

    hdr = {"kind": "header", "schema": 3}
    good = jsonl("good.jsonl", [
        hdr, rnd(1, bytes_on_wire_compressed=204.0, compression_ratio=3.92),
        rnd(2, bytes_on_wire_compressed=204.0, compression_ratio=3.92)])
    missing = jsonl("missing.jsonl", [hdr, rnd(1), rnd(2)])
    dense = jsonl("dense.jsonl", [
        hdr, rnd(1, bytes_on_wire_compressed=800.0, compression_ratio=1.0),
        rnd(2, bytes_on_wire_compressed=800.0, compression_ratio=1.0)])
    v2 = jsonl("v2.jsonl", [{"kind": "header", "schema": 2}, rnd(1)])

    def run(*argv):
        return subprocess.run([sys.executable, script, *argv],
                              capture_output=True, text=True, env=env)

    assert run(good, "--require-comm").returncode == 0
    r = run(missing, "--require-comm")
    assert r.returncode == 1 and "comm series" in r.stdout
    r = run(dense, "--require-comm")
    assert r.returncode == 1 and "not actually compressing" in r.stdout
    assert run(missing).returncode == 0      # fields are optional sans flag
    assert run(v2).returncode == 0           # schema-2 files stay valid
