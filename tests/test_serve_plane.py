"""Serving plane: chunked-prefill bit-identity, paged-pool parity,
engine token equality, scheduler invariants, checkpoint round-trip and
serve telemetry rows.

The load-bearing contract is BIT-identity: the jitted chunked prefill
and the paged decode/prefill paths must produce bitwise the same logits
AND cache contents as the seed per-token dense loop, so switching
engines can never change served tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import restore_params, save
from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.models import attention as attn
from repro.models.api import build_model
from repro.obs.log import MetricsLogger, validate_rows
from repro.serve import (KVPool, LoopEngine, PagedEngine, Request,
                         Scheduler, latency_percentiles)


# --------------------------------------------------------------- fixtures
def _build(cfg):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def dense():
    return _build(reduced(ARCHS["minitron-8b"]))


@pytest.fixture(scope="module")
def swa8():
    # window 8 < prompt lengths below -> the ring WRAPS during prefill
    return _build(reduced(ARCHS["minitron-8b"]).with_(sliding_window=8))


@pytest.fixture(scope="module")
def encdec():
    return _build(reduced(ARCHS["whisper-medium"]))


def _prompts(cfg, B, P, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(1, cfg.vocab_size, (B, P)), jnp.int32)


def _init_cache(model, params, B, max_len):
    if model.cfg.family == "audio":
        fe = jnp.zeros((B, model.cfg.encoder_seq, model.cfg.d_model),
                       jnp.dtype(model.cfg.dtype))
        return model.init_decode_cache(params, fe, max_len)
    return model.init_decode_cache(params, B, max_len)


def _per_token(model, params, prompts, max_len):
    B, P = prompts.shape
    cache = _init_cache(model, params, B, max_len)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(P):
        lg, cache = step(params, prompts[:, t],
                         jnp.full((B,), t, jnp.int32), cache)
        outs.append(lg)
    return jnp.stack(outs, 1), cache


def _chunked(model, params, prompts, max_len, c, pad_fill=0):
    B, P = prompts.shape
    cache = _init_cache(model, params, B, max_len)
    pf = jax.jit(model.prefill)
    lgs = []
    for t0 in range(0, P, c):
        n = min(c, P - t0)
        toks = np.full((B, c), pad_fill, np.int32)
        poss = np.full((B, c), attn.PAD_POS, np.int32)
        toks[:, :n] = np.asarray(prompts[:, t0:t0 + n])
        poss[:, :n] = np.arange(t0, t0 + n)
        lg, cache = pf(params, jnp.asarray(toks), jnp.asarray(poss), cache)
        lgs.append(lg[:, :n])
    return jnp.concatenate(lgs, 1), cache


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------- chunked prefill bit-identity
@pytest.mark.parametrize("fix,c", [("dense", 4), ("swa8", 5),
                                   ("encdec", 4)])
def test_prefill_bit_identical(fix, c, request):
    """Chunked prefill == per-token decode, bitwise, logits AND cache —
    incl. a ragged final chunk (P % c != 0) whose PAD tail must be
    inert, and (swa8) prompts that wrap the sliding-window ring."""
    model, params = request.getfixturevalue(fix)
    B, P, max_len = 2, 11, 20
    prompts = _prompts(model.cfg, B, P)
    ref_lg, ref_c = _per_token(model, params, prompts, max_len)
    blk_lg, blk_c = _chunked(model, params, prompts, max_len, c)
    assert bool(jnp.all(ref_lg == blk_lg))
    assert _trees_equal(ref_c, blk_c)


def test_prefill_pad_garbage_inert(dense):
    """PAD positions are fully predicated: garbage token ids under PAD
    must not perturb logits or cache by a single bit."""
    model, params = dense
    prompts = _prompts(model.cfg, 2, 7)          # 7 % 3 != 0 -> PAD tail
    lg0, c0 = _chunked(model, params, prompts, 16, 3, pad_fill=0)
    lg1, c1 = _chunked(model, params, prompts, 16, 3,
                       pad_fill=model.cfg.vocab_size - 1)
    assert bool(jnp.all(lg0 == lg1))
    assert _trees_equal(c0, c1)


# ------------------------------------------------- paged vs dense parity
@pytest.mark.parametrize("fix", ["dense", "swa8"])
def test_paged_bit_identical_to_dense(fix, request):
    """Paged decode AND paged chunked prefill == the dense cache path,
    bitwise, when the block table covers the same ring (mb*bs == L)."""
    model, params = request.getfixturevalue(fix)
    cfg = model.cfg
    B, P, max_len, bs = 2, 12, 24, 4
    L = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    mb = L // bs
    assert mb * bs == L
    prompts = _prompts(cfg, B, P)
    ref, _ = _per_token(model, params, prompts, max_len)

    nb = 1 + B * mb
    table = jnp.asarray(
        np.arange(1, nb, dtype=np.int32).reshape(B, mb))
    lw = jnp.full((B,), L, jnp.int32)

    pool = model.init_paged_pool(nb, bs)
    pstep = jax.jit(model.decode_step_paged)
    outs = []
    for t in range(P):
        lg, pool = pstep(params, prompts[:, t],
                         jnp.full((B,), t, jnp.int32), pool, table, lw)
        outs.append(lg)
    assert bool(jnp.all(ref == jnp.stack(outs, 1)))

    pool2 = model.init_paged_pool(nb, bs)
    ppf = jax.jit(model.prefill_paged)
    c = 5
    lgs = []
    for t0 in range(0, P, c):
        n = min(c, P - t0)
        toks = np.zeros((B, c), np.int32)
        poss = np.full((B, c), attn.PAD_POS, np.int32)
        toks[:, :n] = np.asarray(prompts[:, t0:t0 + n])
        poss[:, :n] = np.arange(t0, t0 + n)
        lg, pool2 = ppf(params, jnp.asarray(toks), jnp.asarray(poss),
                        pool2, table, lw)
        lgs.append(lg[:, :n])
    assert bool(jnp.all(ref == jnp.concatenate(lgs, 1)))
    assert _trees_equal(pool, pool2)     # same blocks written, same bits


# ------------------------------------------------- engines: e2e equality
def _mkreqs(vocab, lens, max_new, seed=1):
    rng = np.random.RandomState(seed)
    return [Request(rid=i, max_new=max_new,
                    prompt=rng.randint(1, vocab, (ln,)).tolist())
            for i, ln in enumerate(lens)]


def test_engines_serve_identical_tokens(dense):
    """loop(per-token) == loop(chunked prefill) == paged continuous
    batching, token for token — with more requests than slots, so the
    paged run exercises slot reuse and block recycling."""
    model, params = dense
    vocab = model.cfg.vocab_size
    lens, max_new = [5, 11, 8, 14], 6
    ra = LoopEngine(model, params).run(_mkreqs(vocab, lens, max_new))
    rb = LoopEngine(model, params, prefill_chunk=4).run(
        _mkreqs(vocab, lens, max_new))
    eng = PagedEngine(model, params, max_slots=2, block_size=4,
                      max_batch_tokens=64, prefill_chunk=4)
    rc = eng.run(_mkreqs(vocab, lens, max_new))
    for x, y, z in zip(ra, rb, rc):
        assert x["tokens"] == y["tokens"] == z["tokens"]
        assert x["new_tokens"] == max_new
    # results come back in submission order regardless of finish order
    assert [r["id"] for r in rc] == list(range(len(lens)))


def test_loop_engine_pads_never_enter_cache(dense):
    """Variable-length prompts in the lockstep loop: each row's tokens
    must match a solo run of that row (the seed fed row 0's layout to
    every row, corrupting shorter prompts)."""
    model, params = dense
    vocab = model.cfg.vocab_size
    reqs = _mkreqs(vocab, [4, 9], 5)
    both = LoopEngine(model, params).run(
        _mkreqs(vocab, [4, 9], 5))
    for i, r in enumerate(reqs):
        solo = LoopEngine(model, params).run(
            [Request(rid=0, prompt=list(r.prompt), max_new=5)])
        assert solo[0]["tokens"] == both[i]["tokens"]


def test_paged_engine_checkpoint_restore_serves_identically(dense,
                                                            tmp_path):
    """Params through a save/restore round-trip serve bit-identical
    tokens — serving a restored federated model is the product path."""
    model, params = dense
    path = str(tmp_path / "params.npz")
    save(path, params)
    back = restore_params(path, params)
    vocab = model.cfg.vocab_size
    r0 = PagedEngine(model, params, max_slots=2, block_size=4,
                     prefill_chunk=4).run(_mkreqs(vocab, [6, 13], 5))
    r1 = PagedEngine(model, back, max_slots=2, block_size=4,
                     prefill_chunk=4).run(_mkreqs(vocab, [6, 13], 5))
    assert [r["tokens"] for r in r0] == [r["tokens"] for r in r1]


def test_loop_engine_serves_recurrent_family():
    """ssm family has no KV ring -> LoopEngine per-token still serves
    it (and PagedEngine refuses it loudly)."""
    model, params = _build(reduced(ARCHS["rwkv6-3b"]))
    out = LoopEngine(model, params).run(
        _mkreqs(model.cfg.vocab_size, [4, 7], 3))
    assert all(r["new_tokens"] == 3 for r in out)
    with pytest.raises(ValueError, match="no paged serving path"):
        PagedEngine(model, params)


# ------------------------------------------------- scheduler invariants
def test_scheduler_fifo_no_starvation_and_budget():
    # footprints (prompt + max_new): rid0=10, rid1=12, rid2=6, rid3=4
    s = Scheduler(max_batch_tokens=20)
    for i, (p, n) in enumerate([(6, 4), (8, 4), (4, 2), (2, 2)]):
        s.submit(Request(rid=i, prompt=[1] * p, max_new=n))

    def drain():
        out = []
        while True:
            r = s.try_admit(can_place=lambda r: True)
            if r is None:
                return out
            out.append(r)

    # rid0 fits (10 <= 20); head rid1 would hit 22 > 20 -> blocked, and
    # FIFO means rid2 (which WOULD fit) must not jump the queue
    assert [r.rid for r in drain()] == [0]
    s.release(s.inflight[0])
    # rid1 (12), then rid2 (12+6=18 <= 20); rid3 would hit 22 -> blocked
    assert [r.rid for r in drain()] == [1, 2]
    s.release(s.inflight[2])
    assert [r.rid for r in drain()] == [3]
    assert s.admitted_order == s.submitted_order    # nobody overtaken
    assert s.peak_inflight_tokens <= 20


def test_scheduler_oversized_head_admitted_when_idle():
    """A request larger than the whole budget must still run (when
    nothing is in flight) rather than wedge the queue forever."""
    s = Scheduler(max_batch_tokens=8)
    s.submit(Request(rid=0, prompt=[1] * 20, max_new=4))
    r = s.try_admit(can_place=lambda r: True)
    assert r is not None and r.rid == 0


def test_paged_engine_scheduler_and_pool_invariants(dense):
    """After a full run: FIFO admission order, every slot reused, all
    blocks back on the free list (conservation), budget respected."""
    model, params = dense
    vocab = model.cfg.vocab_size
    eng = PagedEngine(model, params, max_slots=2, block_size=4,
                      max_batch_tokens=64, prefill_chunk=4)
    reqs = _mkreqs(vocab, [5, 11, 8, 14, 6], 4)
    out = eng.run(reqs)
    assert all(r["new_tokens"] == 4 for r in out)
    sched, kv = eng.scheduler, eng.kv
    assert sched.admitted_order == sched.submitted_order
    assert sched.peak_inflight_tokens <= 64
    assert sched.pending == 0 and not sched.inflight
    # 5 requests through 2 slots -> at least one slot served >= 3
    assert sum(len(v) for v in sched.slot_history.values()) == len(reqs)
    assert max(len(v) for v in sched.slot_history.values()) >= 3
    # block conservation: everything freed back (block 0 stays reserved)
    assert kv.free_blocks == kv.num_blocks - 1
    assert kv.used_blocks == 0


def test_paged_engine_rejects_unservable_request(dense):
    """A request whose ring cannot fit in the pool fails loudly instead
    of deadlocking the admission loop."""
    model, params = dense
    eng = PagedEngine(model, params, max_slots=1, block_size=4,
                      num_blocks=3, prefill_chunk=4)   # 2 usable blocks
    with pytest.raises(RuntimeError, match="blocks"):
        eng.run(_mkreqs(model.cfg.vocab_size, [20], 4))


def test_kv_pool_alloc_free_roundtrip(dense):
    model, _ = dense
    kv = KVPool(model, num_blocks=5, block_size=4)
    assert kv.free_blocks == 4                  # block 0 reserved
    got = kv.alloc(3)
    assert len(got) == 3 and 0 not in got
    assert kv.used_blocks == 3 and not kv.can_alloc(2)
    kv.free(got)
    assert kv.free_blocks == 4
    # freeing resets the pos entries -> gathered views see "unwritten"
    for g in kv.pool.values():
        assert bool(jnp.all(g["pos"][:, got] == -1))


# ----------------------------------------------------- serve telemetry
def test_metrics_logger_serve_rows_validate(dense):
    model, params = dense
    eng = LoopEngine(model, params)
    results = eng.run(_mkreqs(model.cfg.vocab_size, [4, 7], 3))
    log = MetricsLogger(path=None)
    log.header(extra={"serve": {"engine": "loop"}})
    for r in results:
        log.serve(r)
    log.serve_summary(eng.last_summary)
    assert validate_rows(log.rows) == []
    serve_rows = [r for r in log.rows if r["kind"] == "serve"]
    assert len(serve_rows) == 2
    assert all("tokens" not in r for r in serve_rows)   # ids stay private
    assert [r["new_tokens"] for r in serve_rows] == [3, 3]


def test_latency_percentiles_shape():
    p = latency_percentiles([0.010, 0.020, 0.100])
    assert set(p) == {"p50_ms", "p95_ms", "p99_ms"}
    assert p["p50_ms"] == 20.0 and p["p95_ms"] <= p["p99_ms"]
    assert latency_percentiles([])["p50_ms"] is None
