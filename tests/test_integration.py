"""End-to-end federated runs (miniaturised paper §V): convergence, the
stability claim, async delay tolerance, and the jitted pod round."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, reduced
from repro.configs.registry import ARCHS
from repro.core.round import init_state, make_round_step
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.models.api import build_model


@pytest.fixture(scope="module")
def fl_world():
    train, test = make_image_classification(n_train=1500, n_test=400, seed=0)
    clients = build_clients(train, shard_partition(train["label"], 20, seed=0))
    model = build_model(ARCHS["paper-cnn"])
    return model, clients, test


def _fl(**kw):
    base = dict(num_clients=20, clients_per_round=5, local_epochs=2,
                local_batch_size=25, lr=0.1, p_limited=0.25, seed=0)
    base.update(kw)
    return FLConfig(**base)


def test_ama_fes_converges_noniid(fl_world):
    model, clients, test = fl_world
    sim = FederatedSimulation(model, _fl(algorithm="ama_fes"), clients, test)
    hist = sim.run(rounds=40)
    assert np.mean(hist.test_acc[-5:]) > 0.6          # non-iid 2-class shards, 30 rounds
    assert np.isfinite(hist.train_loss[-1])


def test_async_delays_still_converge(fl_world):
    model, clients, test = fl_world
    fl = _fl(algorithm="ama_fes", p_delay=0.3, max_delay=5)
    sim = FederatedSimulation(model, fl, clients, test)
    hist = sim.run(rounds=40)
    assert np.mean(hist.test_acc[-5:]) > 0.55


def test_ama_more_stable_than_fedavg(fl_world):
    """The paper's headline claim, miniaturised: AMA's late-round accuracy
    variance is lower than naive FL's under non-iid + limited devices."""
    model, clients, test = fl_world
    var, acc = {}, {}
    for algo in ("ama_fes", "fedavg"):
        sim = FederatedSimulation(model, _fl(algorithm=algo, p_limited=0.5),
                                  clients, test)
        hist = sim.run(rounds=60)
        var[algo] = hist.stability_variance(last=20)
        acc[algo] = float(np.mean(hist.test_acc[-10:]))
    assert var["ama_fes"] < var["fedavg"]          # stability (Fig. 2 right)
    assert acc["ama_fes"] > acc["fedavg"]          # accuracy  (Fig. 2)


def test_pod_round_all_algorithms():
    """The jitted pod-scale round runs for every algorithm on a reduced
    transformer, losses finite, params move."""
    cfg = reduced(ARCHS["minitron-8b"])
    model = build_model(cfg)
    C, steps, b, S = 2, 2, 2, 16
    batch = {"tokens": jnp.ones((C, steps, b, S), jnp.int32)}
    sched = {"limited": jnp.asarray([True, False]),
             "delayed": jnp.asarray([True, False]),
             "delays": jnp.asarray([1, 2], jnp.int32),
             "data_sizes": jnp.ones((C,), jnp.float32)}
    for algo, md in [("ama_fes", 0), ("ama_fes", 3), ("fedavg", 0),
                     ("fedprox", 0)]:
        fl = FLConfig(algorithm=algo, max_delay=md, p_delay=0.3 if md else 0,
                      lr=0.05)
        state = init_state(model, fl, jax.random.PRNGKey(0))
        step = jax.jit(make_round_step(model, fl))
        p0 = jax.tree.map(jnp.copy, state["params"])
        for _ in range(2):
            state, metrics = step(state, batch, sched)
        assert np.isfinite(float(metrics["loss"])), algo
        moved = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(p0),
                            jax.tree.leaves(state["params"])))
        assert moved, algo
