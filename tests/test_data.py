"""Data substrate: synthetic sets + non-iid partition properties."""
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.partition import (dirichlet_partition, iid_partition,
                                  shard_partition)
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification, make_lm_tokens


def test_synth_images_shapes_and_classes():
    train, test = make_image_classification(n_train=500, n_test=100)
    assert train["image"].shape == (500, 28, 28, 1)
    assert set(np.unique(train["label"])) <= set(range(10))
    # classes are distinguishable: per-class means differ
    m0 = train["image"][train["label"] == 0].mean(0)
    m1 = train["image"][train["label"] == 1].mean(0)
    assert np.abs(m0 - m1).mean() > 0.05


@settings(deadline=None, max_examples=20)
@given(st.integers(5, 30), st.integers(200, 800))
def test_shard_partition_two_class_property(num_clients, n):
    """The paper's non-iid setting: every client sees at most 2 classes
    (feasible regime: 2*num_clients >= n_classes, like the paper's K=50)."""
    rng = np.random.RandomState(0)
    labels = rng.randint(0, 10, n)
    parts = shard_partition(labels, num_clients)
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert len(all_idx) == n and len(set(all_idx.tolist())) == n  # exact cover
    for idx in parts:
        assert len(np.unique(labels[idx])) <= 2


def test_shard_partition_degenerate_still_exact_cover():
    """With fewer slots than classes, cover beats the 2-class property."""
    rng = np.random.RandomState(0)
    labels = rng.randint(0, 10, 200)
    parts = shard_partition(labels, num_clients=2)
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert sorted(all_idx.tolist()) == list(range(200))


@settings(deadline=None, max_examples=10)
@given(st.integers(2, 20))
def test_dirichlet_partition_is_exact_cover(num_clients):
    rng = np.random.RandomState(1)
    labels = rng.randint(0, 10, 400)
    parts = dirichlet_partition(labels, num_clients, alpha=0.5)
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert sorted(all_idx.tolist()) == list(range(400))


def test_client_sampling_shapes():
    train, _ = make_image_classification(n_train=300, n_test=50)
    clients = build_clients(train, iid_partition(300, 10))
    rng = np.random.RandomState(0)
    out = clients[0].sample_steps(rng, steps=5, batch_size=8)
    assert out["image"].shape == (5, 8, 28, 28, 1)
    assert out["label"].shape == (5, 8)


def test_lm_tokens_topics():
    d = make_lm_tokens(n_seqs=12, seq_len=64, vocab=512, n_topics=4)
    assert d["tokens"].shape == (12, 64)
    assert d["tokens"].max() < 512
