"""utils.hlo — HLO text post-processing used by the roofline analysis
and the bytes-on-wire CI gates."""
import jax
import jax.numpy as jnp

from repro.utils.hlo import (COLLECTIVE_OPS, _shape_bytes, collective_stats,
                             count_op)

_HLO = """\
HloModule jit_step
  %ag = bf16[512,4]{1,0} all-gather(%p), replica_groups={{0,1}}
  %ar = f32[128]{0} all-reduce(%x), to_apply=%sum
  %ars = f32[128]{0} all-reduce-start(%x)
  %ard = f32[128]{0} all-reduce-done(%ars)
  %rs = f32[64]{0} reduce-scatter(%x), dimensions={0}
  %add = f32[128]{0} add(%x, %y)
  %fus = f32[128]{0} fusion(%x), kind=kLoop
"""


def test_shape_bytes_dtypes_and_dims():
    assert _shape_bytes("f32[4,8]") == 4 * 8 * 4
    assert _shape_bytes("bf16[16]{0}") == 16 * 2
    assert _shape_bytes("s32[]") == 4            # scalar: one element
    assert _shape_bytes("pred[3]") == 3
    # tuple shapes sum their components
    assert _shape_bytes("(f32[2], s32[2])") == 8 + 8
    # unknown dtype tokens contribute nothing
    assert _shape_bytes("token[]") == 0


def test_collective_stats_counts_and_bytes():
    st = collective_stats(_HLO)
    assert st.counts["all-gather"] == 1
    # -start counts, -done is skipped (no double counting)
    assert st.counts["all-reduce"] == 2
    assert st.counts["reduce-scatter"] == 1
    assert st.bytes_["all-gather"] == 512 * 4 * 2
    assert st.bytes_["all-reduce"] == 2 * 128 * 4
    assert st.total_count == 4
    assert st.total_bytes == 512 * 4 * 2 + 2 * 128 * 4 + 64 * 4
    assert "all-gather: n=1" in st.summary()


def test_collective_stats_ignores_non_collectives():
    st = collective_stats(_HLO)
    assert set(st.counts) <= set(COLLECTIVE_OPS)
    assert collective_stats("").summary() == "none"


def test_count_op():
    assert count_op(_HLO, "fusion") == 1
    assert count_op(_HLO, "all-reduce") == 1     # exact-name match only
    assert count_op(_HLO, "missing-op") == 0


def test_single_device_lowering_has_no_collectives():
    txt = jax.jit(lambda x: (x * 2).sum()).lower(
        jnp.zeros((8, 8))).compile().as_text()
    assert collective_stats(txt).total_count == 0
