"""FES (paper Eqs. 2-3): classifier/feature-extractor split + freezing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, reduced
from repro.configs.registry import ARCHS
from repro.core import fes
from repro.core.client import make_fes_local_train, make_local_train
from repro.models.api import CLASSIFIER_KEYS, build_model


def _cnn_setup():
    cfg = ARCHS["paper-cnn"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = {"image": jnp.asarray(rng.randn(1, 2, 8, 28, 28, 1), jnp.float32),
             "label": jnp.asarray(rng.randint(0, 10, (1, 2, 8)), jnp.int32)}
    return cfg, model, params, batch


def test_split_matches_paper_cnn():
    """Paper: classifier = the three FC layers; extractor = the convs."""
    _, model, params, _ = _cnn_setup()
    clf, body = fes.split_params(params)
    assert set(clf) == {"fc1", "fc2", "fc3"}
    assert set(body) == {"body"}


def test_limited_client_keeps_feature_extractor_frozen():
    """Dynamic-mask mode: a limited client's conv weights are bit-identical
    after local training; an unlimited client's are not."""
    cfg, model, params, batch = _cnn_setup()
    fl = FLConfig(algorithm="ama_fes", lr=0.1)
    lt = jax.jit(make_local_train(model, fl))
    for limited, expect_frozen in [(True, True), (False, False)]:
        out, _ = lt(params, batch, jnp.asarray([limited]))
        conv_new = np.asarray(out["body"]["conv1"]["w"][0])
        conv_old = np.asarray(params["body"]["conv1"]["w"])
        same = np.array_equal(conv_new, conv_old)
        assert same == expect_frozen
        fc_new = np.asarray(out["fc3"]["w"][0])
        assert not np.array_equal(fc_new, np.asarray(params["fc3"]["w"]))


def test_static_fes_equals_masked_fes():
    """The static (classifier-only-grad) path and the dynamic masked path
    must produce identical classifiers."""
    cfg, model, params, batch = _cnn_setup()
    fl = FLConfig(algorithm="ama_fes", lr=0.1)
    dyn, _ = jax.jit(make_local_train(model, fl))(
        params, batch, jnp.asarray([True]))
    stat, _ = jax.jit(make_fes_local_train(model, fl))(params, batch)
    for a, b in zip(jax.tree.leaves(dyn), jax.tree.leaves(stat)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_count_trainable_matches_classifier_keys():
    """count_trainable under the CLASSIFIER_KEYS mask must equal the
    exact parameter counts of the classifier subtree vs the whole CNN
    (and numpy is imported at module level, not per call)."""
    import jax

    _, model, params, _ = _cnn_setup()
    mask = {k: jax.tree.map(lambda _: k in CLASSIFIER_KEYS, v)
            for k, v in params.items()}
    train, total = fes.count_trainable(params, mask)
    exp_total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    exp_train = sum(int(np.prod(x.shape))
                    for k, v in params.items() if k in CLASSIFIER_KEYS
                    for x in jax.tree.leaves(v))
    assert (train, total) == (exp_train, exp_total)
    assert 0 < train < total
    # all-trainable / none-trainable corners
    ones = jax.tree.map(lambda _: True, params)
    assert fes.count_trainable(params, ones) == (exp_total, exp_total)
    zeros = jax.tree.map(lambda _: False, params)
    assert fes.count_trainable(params, zeros)[0] == 0
    # the module-level import satellite: no function-local numpy import
    import inspect
    assert "import numpy" not in inspect.getsource(fes.count_trainable)


def test_fes_mask_covers_transformer_tail():
    cfg = reduced(ARCHS["minitron-8b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mask = model.fes_mask(params)
    assert all(jax.tree.leaves(mask["tail"]))
    assert all(jax.tree.leaves(mask["lm_head"]))
    assert not any(jax.tree.leaves(mask["body"]))
    assert not any(jax.tree.leaves(mask["embed"]))
    train, total = fes.count_trainable(params, mask)
    assert 0 < train < total
