"""Property tests for the comm-plane codecs (hypothesis-gated, nightly).

Tier-1 installs no hypothesis, so this whole module self-skips there;
the nightly CI job un-skips it (same split as tests/test_partition.py).
The deterministic spot-check versions of these invariants run tier-1 in
tests/test_comm_plane.py — here hypothesis drives the codec math over
adversarial magnitudes (denormals, huge dynamic range, constant rows):

  * q8 — stochastic int8 round trip obeys the elementwise bound
    |e - dq| <= scale with scale = max|e|/127 per row, for ANY finite
    input row;
  * top-k — the kept coordinate set carries at least as much |.| mass
    as any k coordinates, i.e. exactly the k largest magnitudes
    (stated tie-safely via the mass, not the index set);
  * bf16 error feedback — the residual telescopes EXACTLY: at every
    round q_t + r_t == e_t in f32 (an f32's bf16 rounding error is
    exactly representable), so compressed sums + final residual
    reproduce the dense sum. The one concession: XLA may flush a
    DENORMAL residual to zero, so "exact" is bitwise above the
    smallest normal f32 and bounded by it below.
"""
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis",
                          reason="property tests need hypothesis")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.comm.plane import (bf16_encode, decode, q8_encode,  # noqa: E402
                              topk_encode)

SETTINGS = settings(max_examples=40, deadline=None)

finite = st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                   width=32)
rows = st.lists(
    st.lists(finite, min_size=4, max_size=64).map(np.float32),
    min_size=1, max_size=4).filter(
        lambda ls: len({len(r) for r in ls}) == 1)


@SETTINGS
@given(rows=rows, seed=st.integers(0, 2**31 - 1))
def test_q8_roundtrip_error_bounded_and_int8(rows, seed):
    e = jnp.asarray(np.stack(rows), jnp.float32)
    payload, dq = q8_encode(jax.random.PRNGKey(seed), e)
    assert payload["d"].dtype == jnp.int8
    scale = np.asarray(payload["scale"], np.float64)
    err = np.abs(np.asarray(e, np.float64) - np.asarray(dq, np.float64))
    # |e - q*scale| <= scale elementwise (stochastic floor lands on one
    # of the two bracketing integers; clip only triggers at |y| = 127)
    assert np.all(err <= scale[:, None] * (1 + 1e-6))
    # decode() reproduces the encoder's own dequantization exactly
    np.testing.assert_array_equal(np.asarray(decode(payload, e.shape[1])),
                                  np.asarray(dq))


@SETTINGS
@given(rows=rows, frac=st.floats(0.05, 1.0))
def test_topk_keeps_the_k_largest_magnitudes(rows, frac):
    e = jnp.asarray(np.stack(rows), jnp.float32)
    n = e.shape[1]
    kk = max(1, min(n, int(frac * n)))
    payload, dq = topk_encode(e, kk)
    assert payload["v"].shape == payload["i"].shape == (e.shape[0], kk)
    ea = np.abs(np.asarray(e, np.float64))
    kept = np.abs(np.asarray(payload["v"], np.float64))
    for r in range(e.shape[0]):
        # tie-safe statement of "the k largest": the kept mass equals
        # the sum of the k largest |e| (any argsort tiebreak ok)
        want = np.sort(ea[r])[::-1][:kk].sum()
        assert kept[r].sum() == pytest.approx(want, rel=1e-9)
    # dense reconstruction touches at most kk coordinates per row
    assert np.count_nonzero(np.asarray(dq), axis=1).max() <= kk


@SETTINGS
@given(rows=rows, n_rounds=st.integers(1, 5))
def test_bf16_error_feedback_telescopes_exactly(rows, n_rounds):
    """Per-round EXACT split e_t = q_t + r_t in f32 arithmetic, so
    sum(q_t) + r_T == sum(d_t) up to f32 summation order — the
    compressed stream loses nothing the residual does not carry."""
    tiny = np.finfo(np.float32).tiny        # smallest NORMAL f32
    d = jnp.asarray(np.stack(rows), jnp.float32)
    r = jnp.zeros_like(d)
    q_sum = np.zeros(d.shape, np.float64)
    for _ in range(n_rounds):
        e = d + r
        payload, dq = bf16_encode(e)
        assert payload["d"].dtype == jnp.bfloat16
        r = e - dq
        # the defining exactness: dq + r == e bitwise (bf16 rounding
        # error of an f32 is exactly representable in f32) — except
        # that XLA may flush a DENORMAL residual to zero, so any
        # discrepancy must sit strictly below the normal range
        diff = np.abs(np.asarray(dq + r, np.float64) - np.asarray(e))
        assert np.all((diff == 0) | (diff < tiny))
        q_sum += np.asarray(dq, np.float64)
    dense_sum = n_rounds * np.asarray(d, np.float64)
    np.testing.assert_allclose(q_sum + np.asarray(r, np.float64),
                               dense_sum, rtol=1e-6, atol=1e-6)
