"""Million-client federation machinery: hashed virtual populations,
O(cohort) selection, streamed shard staging, and the pre-reduced client
axis.

The safety nets for the scale PR:
  * the dense draw sequence is UNTOUCHED below the guards (rng.choice
    up to DENSE_SELECT_MAX clients, dense arrays up to VIRTUAL_K_MIN) —
    the seed's bit-identity contract survives;
  * the virtual path honours the SAME contracts as the dense one
    (batch row i == round(t0+i), purity in t, fresh-instance agreement)
    at K = 10^5;
  * VirtualClientShards stages bit-identical batches to a dense
    ClientDataset list built from the same shard views — so the whole
    engine run (5 strategies x scan / per-round loop) is bit-identical
    streamed vs dense;
  * reduced_server_update (the sharded-client-axis path) matches the
    fused server plane for every registered strategy, params AND aux.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import env as env_mod
from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core import strategies
from repro.core.simulation import FederatedSimulation
from repro.data.pipeline import (ClientDataset, VirtualClientShards,
                                 stage_round_indices)
from repro.data.synth import make_image_classification
from repro.env.base import UniformParticipation
from repro.env.virtual import (DENSE_SELECT_MAX, VIRTUAL_K_MIN,
                               floyd_sample, is_virtual,
                               select_batch_hashed)
from repro.models.api import build_model

CANONICAL = sorted({cls.name for cls in map(env_mod.get, env_mod.names())})
#: environments with a K-free realisation (trace replay stays dense)
VIRT_ENVS = [n for n in CANONICAL if env_mod.get(n).supports_virtual]

STRATS = [("ama", 0), ("async_ama", 3), ("fedavg", 0), ("fedprox", 0),
          ("fedopt", 0)]


@pytest.fixture(scope="module")
def small_world():
    train, test = make_image_classification(n_train=240, n_test=60, seed=0)
    model = build_model(ARCHS["paper-cnn"])
    return model, train, test


def _fl(**kw):
    base = dict(num_clients=20, clients_per_round=5, local_epochs=1,
                local_batch_size=10, lr=0.1, p_limited=0.25, seed=0)
    base.update(kw)
    return FLConfig(**base)


def assert_states_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------ O(m) selection ----------

def test_dense_select_guard_is_bit_identical():
    """Below DENSE_SELECT_MAX the draw must stay EXACTLY rng.choice —
    any change breaks every committed seed at paper scale."""
    fl = _fl(num_clients=256, clients_per_round=7)
    got = UniformParticipation(fl).select(0, np.random.RandomState(7))
    want = np.random.RandomState(7).choice(
        256, size=7, replace=False).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_floyd_sample_valid_and_deterministic():
    K, m = 1_000_000, 257
    a = floyd_sample(np.random.RandomState(11), K, m)
    b = floyd_sample(np.random.RandomState(11), K, m)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int32 and a.shape == (m,)
    assert len(np.unique(a)) == m
    assert a.min() >= 0 and a.max() < K
    # O(m), not O(K): the rng consumed m draws, not a K permutation
    assert DENSE_SELECT_MAX < K


def test_select_batch_hashed_contract():
    fl = _fl(num_clients=1_000_000, clients_per_round=128,
             population="virtual")
    sel = select_batch_hashed(fl, 5, 16)
    assert sel.shape == (16, 128) and sel.dtype == np.int32
    assert sel.min() >= 0 and sel.max() < 1_000_000
    for row in sel:                       # without replacement per round
        assert len(np.unique(row)) == 128
    # pure in t: any chunking yields the same rows
    np.testing.assert_array_equal(select_batch_hashed(fl, 8, 1)[0], sel[3])
    np.testing.assert_array_equal(select_batch_hashed(fl, 5, 4), sel[:4])


def test_is_virtual_guard():
    assert not is_virtual(_fl())                       # auto, tiny K
    assert is_virtual(_fl(num_clients=VIRTUAL_K_MIN + 1))
    assert not is_virtual(_fl(num_clients=VIRTUAL_K_MIN + 1,
                              population="dense"))
    assert is_virtual(_fl(population="virtual"))
    with pytest.raises(ValueError):
        is_virtual(_fl(population="bogus"))


# ------------------------------------------ virtual environment layer -----

@pytest.mark.parametrize("name", VIRT_ENVS)
def test_virtual_batch_rows_bit_identical_to_rounds(name):
    """THE schedule contract, at K = 10^5 where the dense path would
    materialise (K,) state: batch row i == round(t0 + i), and a fresh
    instance queried out of order agrees."""
    fl = _fl(num_clients=100_000, clients_per_round=8, env=name,
             p_delay=0.4, max_delay=6)
    e = env_mod.get(name)(fl)
    assert e.virtual
    got = e.batch(3, 5)
    assert got["selected"].shape == (5, 8)
    for i in range(5):
        rs = e.round(3 + i)
        np.testing.assert_array_equal(got["selected"][i], rs.selected)
        np.testing.assert_array_equal(got["limited"][i], rs.limited)
        np.testing.assert_array_equal(got["delayed"][i], rs.delayed)
        np.testing.assert_array_equal(got["delays"][i], rs.delays)
        np.testing.assert_array_equal(got["data_sizes"][i], rs.data_sizes)
    fresh = env_mod.get(name)(fl)
    rs = fresh.round(7)                   # first query, deep into the run
    np.testing.assert_array_equal(got["delays"][4], rs.delays)


def test_trace_env_never_virtual():
    """Trace replay is a recording of a CONCRETE population — it must
    refuse the virtual realisation even when the guard would fire."""
    assert env_mod.get("trace").supports_virtual is False
    e = env_mod.get("trace")(_fl(env="trace", population="virtual"))
    assert not e.virtual


# ------------------------------------------------ streamed staging --------

def test_shard_views_are_pure_and_overlapping(small_world):
    _, train, _ = small_world
    K = 1000                              # K x shard_size >> n: wraps
    a = VirtualClientShards(train, K, shard_size=24, seed=3)
    b = VirtualClientShards(train, K, shard_size=24, seed=3)
    assert len(a) == K and a.min_size == 24
    np.testing.assert_array_equal(a.shard_indices(917), b.shard_indices(917))
    assert not np.array_equal(a.shard_indices(0), a.shard_indices(1))
    for i in (0, 1, 999):
        idx = a.shard_indices(i)
        assert idx.shape == (24,) and idx.min() >= 0 and idx.max() < 240
    sizes = a.client_sizes(np.array([[3, 917], [5, 0]]))
    np.testing.assert_array_equal(sizes, np.full((2, 2), 24, np.float32))


def test_streamed_staging_matches_dense_list(small_world):
    """VirtualClientShards and a dense ClientDataset list built from the
    SAME shard views consume the shared per-round stream identically."""
    _, train, _ = small_world
    shards = VirtualClientShards(train, 20, shard_size=24, seed=0)
    dense = [ClientDataset(train, shards.shard_indices(i))
             for i in range(20)]
    sel = np.array([3, 19, 0, 7, 11])
    for t in (0, 9):
        np.testing.assert_array_equal(
            stage_round_indices(shards, sel, 0, t, steps=2, batch_size=10),
            stage_round_indices(dense, sel, 0, t, steps=2, batch_size=10))


@pytest.mark.parametrize("use_scan", [True, False])
@pytest.mark.parametrize("algo,md", STRATS)
def test_streamed_engine_bit_identical_to_dense(small_world, algo, md,
                                                use_scan):
    """The whole engine run — every strategy, fused scan AND per-round
    loop — is bit-identical streamed (VirtualClientShards) vs dense
    (ClientDataset list over the same shard views)."""
    model, train, test = small_world
    fl = _fl(algorithm=algo, env="bernoulli", max_delay=md,
             p_delay=0.4 if md else 0.0)
    shards = VirtualClientShards(train, 20, shard_size=24, seed=0)
    dense = [ClientDataset(train, shards.shard_indices(i))
             for i in range(20)]
    sims = {k: FederatedSimulation(model, fl, c, test, use_scan=use_scan)
            for k, c in (("streamed", shards), ("dense", dense))}
    hists = {k: s.run(rounds=4, eval_every=2) for k, s in sims.items()}
    assert_states_identical(sims["streamed"].state, sims["dense"].state)
    assert hists["streamed"].train_loss == hists["dense"].train_loss
    assert hists["streamed"].test_acc == hists["dense"].test_acc


def test_prefetch_depth_is_plumbed_and_bit_identical(small_world):
    model, train, test = small_world
    shards = VirtualClientShards(train, 20, shard_size=24, seed=0)
    runs = {}
    for depth in (1, 3):
        fl = _fl(env="bernoulli", p_delay=0.3, max_delay=4,
                 prefetch_depth=depth)
        assert fl.prefetch_depth == depth
        sim = FederatedSimulation(model, fl, shards, test)
        sim.run(rounds=4, eval_every=2)
        runs[depth] = sim.state
    assert_states_identical(runs[1], runs[3])


# -------------------------------- pre-reduced client axis (sharded) -------

@pytest.mark.parametrize("algo,md", STRATS)
def test_reduced_server_update_matches_fused(algo, md):
    """reduced_server_update — the weighted client-axis contraction that
    runs BEFORE the server plane when the mesh shards "client" — must
    match the fused plane on params AND aux (async ring buffer, fedopt
    moments) for every registered strategy."""
    fl = _fl(algorithm=algo, max_delay=md, p_delay=0.4 if md else 0.0)
    strategy = strategies.get(algo)(fl)
    rng = np.random.RandomState(0)
    C = fl.clients_per_round
    params = {"w": jnp.asarray(rng.randn(6, 4), jnp.float32),
              "b": jnp.asarray(rng.randn(4), jnp.float32)}
    client_params = jax.tree.map(
        lambda p: p + jnp.asarray(rng.randn(C, *p.shape) * 0.1,
                                  jnp.float32), params)
    delayed = jnp.asarray(rng.rand(C) < 0.4) if md else jnp.zeros(C, bool)
    sched = {"data_sizes": jnp.asarray(rng.randint(5, 40, C), jnp.float32),
             "delayed": delayed,
             "delays": jnp.where(delayed, 1 + jnp.asarray(
                 rng.randint(0, max(md, 1), C)), 1).astype(jnp.int32),
             "limited": jnp.zeros(C, bool)}
    aux = strategy.init_state(params)
    t = jnp.asarray(3, jnp.int32)
    fused_p, fused_aux = strategy.fused_server_update(
        t, params, client_params, sched, aux)
    out = strategy.reduced_server_update(
        t, params, client_params, sched, aux)
    assert out is not NotImplemented
    red_p, red_aux = out
    for a, b in zip(jax.tree.leaves(fused_p), jax.tree.leaves(red_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
    for a, b in zip(jax.tree.leaves(fused_aux), jax.tree.leaves(red_aux)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_client_reduce_force_runs_end_to_end(small_world):
    """fl.client_reduce='force' routes every round through the reduced
    path on a 1-device mesh — the CPU equivalence configuration — and
    stays close to the fused default over a short run."""
    model, train, test = small_world
    shards = VirtualClientShards(train, 20, shard_size=24, seed=0)
    states = {}
    for mode in ("off", "force"):
        fl = _fl(env="bernoulli", p_delay=0.3, max_delay=4,
                 algorithm="async_ama", client_reduce=mode)
        sim = FederatedSimulation(model, fl, shards, test)
        sim.run(rounds=3, eval_every=3)
        states[mode] = sim.state
    for a, b in zip(jax.tree.leaves(states["off"]),
                    jax.tree.leaves(states["force"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-4, atol=1e-5)


def test_client_reduce_rejects_unknown_mode(small_world):
    model, train, test = small_world
    shards = VirtualClientShards(train, 20, shard_size=24, seed=0)
    fl = _fl(env="bernoulli", client_reduce="bogus")
    sim = FederatedSimulation(model, fl, shards, test)
    with pytest.raises(ValueError, match="client_reduce"):
        sim.run(rounds=1, eval_every=1)
