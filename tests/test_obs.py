"""The federation telemetry plane (repro.obs).

The contracts the observability PR must keep:

  * enabling ``fl.extended_metrics`` NEVER changes the params stream —
    metrics-on == metrics-off bit-identically, on the fused scan AND
    the per-round fallback, and the two engines agree on the metric
    series themselves;
  * a resumed run's JSONL round/eval rows are the exact tail of the
    uninterrupted run's file (the log analogue of checkpoint
    bit-identity; header/phases rows are wall-clock and excluded);
  * ``History.final_accuracy`` / ``stability_variance`` window by
    ROUNDS, not eval points (the seed's ``eval_every > 1`` unit bug),
    and the report CLI reproduces them exactly from the file alone;
  * the JSONL schema is validated (``validate_rows`` /
    scripts/check_metrics.py — the CI gate on launcher output).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.exec.engine import History
from repro.models.api import build_model
from repro.obs.log import (SCHEMA_VERSION, MetricsLogger, read_rows,
                           validate_rows)
from repro.obs.metrics import (ROUND_METRIC_KEYS, payload_bytes,
                               stability_stats, window_by_rounds)
from repro.obs.provenance import COMPARE_KEYS, diff, provenance
from repro.obs.timing import PhaseTimes, sync_time

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def small_world():
    train, test = make_image_classification(n_train=240, n_test=60, seed=0)
    clients = build_clients(train, shard_partition(train["label"], 8, seed=0))
    model = build_model(ARCHS["paper-cnn"])
    return model, clients, test


def _fl(**kw):
    base = dict(num_clients=8, clients_per_round=4, local_epochs=1,
                local_batch_size=10, lr=0.1, p_limited=0.25, seed=0)
    base.update(kw)
    return FLConfig(**base)


ALGOS = [("ama", 0), ("async_ama", 3), ("fedprox", 0)]


def assert_states_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------- metrics bit-identity net ----

@pytest.mark.parametrize("algo,md", ALGOS)
def test_extended_metrics_never_change_params(small_world, algo, md):
    """fl.extended_metrics on vs off, scan vs per-round: all four runs
    produce bit-identical params/aux, and the scan and no-scan engines
    agree on every extended metric series."""
    model, clients, test = small_world
    sims, rows = {}, {}
    for ext in (False, True):
        for scan in (True, False):
            fl = _fl(algorithm=algo, max_delay=md,
                     p_delay=0.4 if md else 0.0, extended_metrics=ext)
            logger = MetricsLogger(None) if ext else None
            sim = FederatedSimulation(model, fl, clients, test,
                                      use_scan=scan, logger=logger)
            sim.run(rounds=4, eval_every=2)
            sims[ext, scan] = sim
            if ext:
                rows[scan] = [r for r in logger.rows
                              if r["kind"] == "round"]
    ref = sims[False, True].state
    for key, sim in sims.items():
        assert_states_identical(ref, sim.state)
    # the two engines log the identical extended series
    assert len(rows[True]) == len(rows[False]) == 4
    for ra, rb in zip(rows[True], rows[False]):
        assert set(ROUND_METRIC_KEYS) <= set(ra)
        assert ra == rb


def test_round_metric_semantics(small_world):
    """Spot-check the series against hand-computable facts: alpha_eff
    follows Eq. 5 for sync AMA, bytes_on_wire = on-time x payload,
    stale_hist counts exactly the delayed cohorts."""
    model, clients, test = small_world
    fl = _fl(algorithm="ama", extended_metrics=True)
    logger = MetricsLogger(None)
    sim = FederatedSimulation(model, fl, clients, test, logger=logger)
    sim.run(rounds=4, eval_every=2)
    payload = payload_bytes(sim.params)
    rnd = [r for r in logger.rows if r["kind"] == "round"]
    for r in rnd:
        # row t counts COMPLETED rounds (1-indexed); Eq. 5's round
        # index is the 0-indexed t the round entered with
        want = min(fl.alpha0 + fl.eta * (r["t"] - 1), fl.alpha_cap)
        assert r["alpha_eff"] == pytest.approx(want, abs=1e-7)
        assert r["bytes_on_wire"] == pytest.approx(
            r["n_on_time"] * payload)
        assert len(r["stale_hist"]) == fl.max_delay + 1
        assert sum(r["stale_hist"]) == r["n_delayed"]
        assert r["n_on_time"] + r["n_delayed"] == fl.clients_per_round


@pytest.mark.parametrize("algo,md", ALGOS)
def test_required_series_present_per_algorithm(small_world, algo, md):
    """ama / async_ama / fedprox all emit the full per-round staleness /
    participation / mix series (the acceptance's three algorithms)."""
    model, clients, test = small_world
    fl = _fl(algorithm=algo, max_delay=md, p_delay=0.4 if md else 0.0,
             extended_metrics=True)
    logger = MetricsLogger(None)
    FederatedSimulation(model, fl, clients, test,
                        logger=logger).run(rounds=2, eval_every=2)
    rnd = [r for r in logger.rows if r["kind"] == "round"]
    assert len(rnd) == 2
    for r in rnd:
        for k in ROUND_METRIC_KEYS + ("loss", "n_on_time", "t"):
            assert k in r, (algo, k)
    if algo == "fedprox":      # pure weighted average: no AMA mix
        assert all(r["alpha_eff"] == 0.0 for r in rnd)


# ------------------------------------------------ JSONL resume contract ----

def test_resume_produces_identical_jsonl_tail(small_world, tmp_path):
    """save -> restore -> continue logs round/eval rows bit-identical to
    the uninterrupted run's tail (header/phases rows are wall-clock and
    excluded from the contract)."""
    model, clients, test = small_world
    fl = _fl(algorithm="async_ama", max_delay=3, p_delay=0.4,
             extended_metrics=True)
    ckpt = str(tmp_path / "state.npz")

    full_log = MetricsLogger(None)
    full = FederatedSimulation(model, fl, clients, test, logger=full_log)
    full.run(rounds=6, eval_every=2)

    part = FederatedSimulation(model, fl, clients, test)
    part.run(rounds=4, eval_every=2)
    part.save(ckpt)

    cont_log = MetricsLogger(None)
    cont = FederatedSimulation(model, fl, clients, test, logger=cont_log)
    cont.resume(ckpt)
    cont.run(rounds=2, eval_every=2)

    def data_rows(log):
        return [r for r in log.rows if r["kind"] in ("round", "eval")]

    tail = [r for r in data_rows(full_log) if r["t"] > 4
            or (r["kind"] == "eval" and r["t"] > 4)]
    assert data_rows(cont_log) == tail
    header = cont_log.rows[0]
    assert header["kind"] == "header" and header["resumed_at"] == 4


# --------------------------------------- round-windowed stability math ----

def test_history_windows_by_rounds_not_eval_points():
    """eval_every=5 regression: stability_variance(last=20) must cover
    the evals of the last 20 ROUNDS (4 points), not the last 20 eval
    points (all 10, silently spanning 50 rounds — the seed bug)."""
    accs = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]
    h = History(test_acc=accs, eval_rounds=list(range(5, 55, 5)))
    s = stability_stats(h.eval_rounds, h.test_acc, last=20)
    assert s["n_evals"] == 4                      # rounds 35,40,45,50
    assert h.final_accuracy(last=20) == pytest.approx(np.mean(accs[-4:]))
    assert h.stability_variance(last=20) == pytest.approx(
        np.var(np.array(accs[-4:]) * 100.0))
    np.testing.assert_array_equal(
        window_by_rounds(h.eval_rounds, 20),
        np.array([False] * 6 + [True] * 4))
    # legacy History without round indices: counts eval points (old
    # behaviour is the only defensible reading of the data it has)
    legacy = stability_stats([], accs, last=4)
    assert legacy["n_evals"] == 4


def test_stability_stats_empty_window():
    s = stability_stats([], [], last=50)
    assert s["n_evals"] == 0
    assert np.isnan(s["final_accuracy"])


# ----------------------------------------------------- report CLI ----

@pytest.fixture(scope="module")
def logged_run(small_world, tmp_path_factory):
    """One paper-CNN run recorded to a real JSONL file + its in-process
    History (the exactness bridge the report must reproduce)."""
    model, clients, test = small_world
    path = str(tmp_path_factory.mktemp("obs") / "run.jsonl")
    fl = _fl(algorithm="ama", extended_metrics=True)
    with MetricsLogger(path) as logger:
        sim = FederatedSimulation(model, fl, clients, test, logger=logger)
        hist = sim.run(rounds=6, eval_every=2)
    return path, hist


def test_report_reproduces_history_exactly(logged_run):
    from repro.obs.report import history_from_rows, summarize
    path, hist = logged_run
    rows = read_rows(path)
    assert validate_rows(rows) == []
    h2 = history_from_rows(rows)
    assert h2.test_acc == hist.test_acc
    assert h2.train_loss == hist.train_loss
    assert h2.eval_rounds == hist.eval_rounds == [2, 4, 6]
    s = summarize(rows, last=4)
    # EXACT equality: same stability_stats on json-round-tripped floats
    assert s["final_accuracy"] == hist.final_accuracy(last=4)
    assert s["stability_variance"] == hist.stability_variance(last=4)
    assert s["rounds"] == 6 and s["algorithm"] == "ama"
    assert s["bytes_on_wire_total"] > 0
    assert "phases" in s


def test_report_cli_render_and_compare(logged_run, capsys):
    from repro.obs.report import main
    path, _ = logged_run
    assert main([path]) == 0
    out = capsys.readouterr().out
    assert "accuracy:" in out and "staleness:" in out and "mix:" in out
    assert main(["--compare", path, path]) == 0
    out = capsys.readouterr().out
    assert "deltas (B - A)" in out
    assert "provenance mismatch" not in out     # same file, same env


def test_report_cli_rejects_invalid_file(tmp_path):
    from repro.obs.report import main
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"kind": "round", "t": 0}) + "\n")
    with pytest.raises(SystemExit) as e:
        main([str(bad)])
    assert e.value.code == 2


# ------------------------------------------------- schema validation ----

def test_validate_rows_accepts_logger_output(small_world):
    model, clients, test = small_world
    logger = MetricsLogger(None)
    FederatedSimulation(model, _fl(extended_metrics=True), clients, test,
                        logger=logger).run(rounds=2, eval_every=2)
    assert validate_rows(logger.rows) == []
    assert logger.rows[0]["schema"] == SCHEMA_VERSION
    assert logger.rows[0]["payload_bytes"] > 0


def test_validate_rows_catches_violations():
    hdr = {"kind": "header", "schema": SCHEMA_VERSION}
    rnd = {"kind": "round", "t": 1, "loss": 1.0, "n_on_time": 4}
    assert validate_rows([]) != []
    assert any("header" in e for e in validate_rows([rnd]))
    assert any("schema" in e for e in
               validate_rows([{"kind": "header", "schema": 99}]))
    assert any("duplicate" in e for e in validate_rows([hdr, hdr]))
    assert any("unknown kind" in e for e in
               validate_rows([hdr, {"kind": "banana"}]))
    assert any("missing keys" in e for e in
               validate_rows([hdr, {"kind": "round", "t": 0}]))
    assert any("not after" in e for e in
               validate_rows([hdr, rnd, dict(rnd)]))
    assert any("beyond last" in e for e in validate_rows(
        [hdr, rnd, {"kind": "eval", "t": 9, "test_acc": .5,
                    "test_loss": 1.0}]))
    assert validate_rows(
        [hdr, rnd, {"kind": "eval", "t": 1, "test_acc": .5,
                    "test_loss": 1.0}]) == []


def test_check_metrics_script(logged_run, tmp_path):
    """scripts/check_metrics.py — the CI gate on launcher JSONL: exit 0
    + OK on a valid extended run, exit 1 on a schema violation."""
    path, _ = logged_run
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    script = os.path.join(ROOT, "scripts", "check_metrics.py")
    ok = subprocess.run([sys.executable, script, path,
                         "--require-extended"],
                        capture_output=True, text=True, env=env)
    assert ok.returncode == 0, ok.stderr
    assert "OK" in ok.stdout
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"kind": "round", "t": 0}) + "\n")
    fail = subprocess.run([sys.executable, script, str(bad)],
                          capture_output=True, text=True, env=env)
    assert fail.returncode == 1


# ------------------------------------------------- timing + provenance ----

def test_phase_times_accumulate_and_sync():
    pt = PhaseTimes()
    with pt.phase("eval") as span:
        span.sync(jax.numpy.ones(4) * 2)
    with pt.phase("eval"):
        pass
    pt.add("stage", 0.5)
    s = pt.summary()
    assert s["eval"]["calls"] == 2 and s["eval"]["seconds"] >= 0
    assert s["stage"] == {"seconds": 0.5, "calls": 1}
    assert pt.total() >= 0.5
    dt, out = sync_time(lambda x: x + 1, jax.numpy.zeros(3))
    assert dt >= 0 and float(out[0]) == 1.0


def test_engine_populates_phase_timer(small_world):
    """A run books compile (first chunk-length specialisation), stage
    and eval phases; a second same-shape chunk books steady-state
    dispatch, not compile."""
    model, clients, test = small_world
    sim = FederatedSimulation(model, _fl(), clients, test)
    sim.run(rounds=4, eval_every=2)
    s = sim.timer.summary()
    for phase in ("compile", "stage", "eval"):
        assert phase in s and s[phase]["seconds"] > 0
    assert s["compile"]["calls"] == 1
    assert s["scan_dispatch"]["calls"] == 1      # the second 2-chunk


def test_provenance_block_and_diff():
    p = provenance()
    for k in COMPARE_KEYS + ("platform", "generated_unix"):
        assert k in p
    assert p["jax_version"] == jax.__version__
    assert diff(p, dict(p)) == []
    other = dict(p, backend="tpu", device_count=8)
    d = diff(p, other)
    assert any(x.startswith("backend:") for x in d)
    assert diff(None, p) == [] and diff(p, None) == []
