"""Optimizers + checkpoint round-trip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import restore, save
from repro.optim import adam, apply_updates, sgd


def _quadratic(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum((params["b"] + 1.0) ** 2)


def test_sgd_converges_quadratic():
    params = {"w": jnp.zeros(4), "b": jnp.zeros(3)}
    init, update = sgd(0.1, momentum=0.9)
    state = init(params)
    for _ in range(300):
        g = jax.grad(_quadratic)(params)
        upd, state = update(g, state)
        params = apply_updates(params, upd)
    assert float(_quadratic(params)) < 1e-4


def test_adam_converges_quadratic():
    params = {"w": jnp.zeros(4), "b": jnp.zeros(3)}
    init, update = adam(0.1)
    state = init(params)
    for _ in range(200):
        g = jax.grad(_quadratic)(params)
        upd, state = update(g, state, params)
        params = apply_updates(params, upd)
    assert float(_quadratic(params)) < 1e-3


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nest": {"b": jnp.ones((4,), jnp.bfloat16),
                     "c": jnp.asarray(3, jnp.int32)}}
    path = str(tmp_path / "ckpt.npz")
    save(path, tree)
    back = restore(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
