"""Optimizers + checkpoint round-trip (incl. the flat-key collision,
unique-tmp-name and round-state-into-serving regressions)."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io
from repro.checkpoint.io import restore, restore_params, save, save_state
from repro.optim import adam, apply_updates, sgd


def _quadratic(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum((params["b"] + 1.0) ** 2)


def test_sgd_converges_quadratic():
    params = {"w": jnp.zeros(4), "b": jnp.zeros(3)}
    init, update = sgd(0.1, momentum=0.9)
    state = init(params)
    for _ in range(300):
        g = jax.grad(_quadratic)(params)
        upd, state = update(g, state)
        params = apply_updates(params, upd)
    assert float(_quadratic(params)) < 1e-4


def test_adam_converges_quadratic():
    params = {"w": jnp.zeros(4), "b": jnp.zeros(3)}
    init, update = adam(0.1)
    state = init(params)
    for _ in range(200):
        g = jax.grad(_quadratic)(params)
        upd, state = update(g, state, params)
        params = apply_updates(params, upd)
    assert float(_quadratic(params)) < 1e-3


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nest": {"b": jnp.ones((4,), jnp.bfloat16),
                     "c": jnp.asarray(3, jnp.int32)}}
    path = str(tmp_path / "ckpt.npz")
    save(path, tree)
    back = restore(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_flatten_rejects_slash_keys(tmp_path):
    """{"a/b": x} and {"a": {"b": y}} land on the SAME flat npz key —
    the old _flatten silently merged them (one leaf lost). Now a clear
    error, raised before anything touches disk."""
    tree = {"a/b": jnp.ones(2), "a": {"b": jnp.zeros(2)}}
    path = str(tmp_path / "bad.npz")
    with pytest.raises(ValueError, match="contains '/'"):
        save(path, tree)
    assert list(tmp_path.iterdir()) == []        # no file, no tmp litter


def test_save_tmp_name_unique_per_writer(tmp_path):
    """Two concurrent checkpointers of the same path must not clobber
    each other's tmp file: tmp names are per-writer unique, and the
    final file is always ONE writer's complete tree."""
    final = str(tmp_path / "c.npz")
    names = {io._tmp_path(final) for _ in range(8)}
    assert len(names) == 8
    trees = [{"w": jnp.full((64,), float(i))} for i in range(2)]
    errs = []

    def writer(tree):
        try:
            for _ in range(20):
                save(final, tree)
        except Exception as e:                   # surfaces on the main thread
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(t,)) for t in trees]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errs == []
    back = np.asarray(restore(final, trees[0])["w"])
    assert float(back[0]) in (0.0, 1.0)          # one writer's tree ...
    assert np.all(back == back[0])               # ... and not interleaved
    assert [p for p in os.listdir(tmp_path) if ".tmp" in p] == []


def test_restore_params_from_round_state_into_serving(tmp_path):
    """serve --checkpoint regression: the trainer's save_state writes
    {params, t, aux} with params/...-prefixed keys, which plain
    restore(path, params) KeyErrors on. restore_params detects the
    round-state layout, slices the params subtree, and the result
    actually serves (greedy decode)."""
    from repro.configs.base import FLConfig, reduced
    from repro.configs.registry import ARCHS
    from repro.core.round import init_state
    from repro.launch.serve import batched_decode
    from repro.models.api import build_model

    cfg = reduced(ARCHS["minitron-8b"])
    model = build_model(cfg)
    fl = FLConfig(algorithm="fedopt")            # stateful aux: Adam moments
    state = init_state(model, fl, jax.random.PRNGKey(0))
    state["t"] = jnp.asarray(7, jnp.int32)
    path = str(tmp_path / "round_state.npz")
    save_state(path, state)

    fresh = model.init(jax.random.PRNGKey(1))
    with pytest.raises(KeyError):                # the bug this fixes
        restore(path, fresh)
    back = restore_params(path, fresh)
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # bare params checkpoints still restore through the same entry point
    bare = str(tmp_path / "params_only.npz")
    save(bare, state["params"])
    back2 = restore_params(bare, fresh)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(back2)[0], np.float32),
        np.asarray(jax.tree.leaves(state["params"])[0], np.float32))
    # and the restored params drive the serving path
    prompts = jnp.asarray([[1, 2]], jnp.int32)
    out = batched_decode(model, back, prompts, max_new=2, max_len=8)
    assert out.shape == (1, 4)
    assert np.all(np.asarray(out) >= 0)
