"""Server-strategy subsystem: registry, seed-equivalence of every ported
strategy, the fedopt extension point, kernel-path parity, and the fused
scan engine vs the sequential per-round loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core import async_ama as aa
from repro.core import strategies
from repro.core.ama import ama_aggregate, fedavg_aggregate
from repro.core.round import init_state, make_round_step, make_train_loop
from repro.models.api import build_model


def tree(rng, C=None):
    f = lambda *s: jnp.asarray(rng.randn(*s), jnp.float32)
    if C is None:
        return {"a": f(3, 4), "b": {"c": f(5)}}
    return {"a": f(C, 3, 4), "b": {"c": f(C, 5)}}


def sched_for(rng, C, max_delay=0):
    delayed = rng.rand(C) < 0.4
    delays = np.where(delayed, rng.randint(1, max(max_delay, 1) + 1, C), 1)
    return {"limited": jnp.asarray(rng.rand(C) < 0.5),
            "delayed": jnp.asarray(delayed),
            "delays": jnp.asarray(delays.astype(np.int32)),
            "data_sizes": jnp.asarray(rng.rand(C) + 0.5, jnp.float32)}


def assert_trees_close(got, want, **kw):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6, **kw)


# ------------------------------------------------------------ registry ----

def test_registry_names_and_resolve():
    assert {"ama", "ama_fes", "async_ama", "fedavg", "fedprox",
            "fedopt"} <= set(strategies.names())
    assert isinstance(strategies.resolve(FLConfig(algorithm="ama_fes")),
                      strategies.AMAStrategy)
    # the seed's implicit upgrade: ama + delays -> async ama
    s = strategies.resolve(FLConfig(algorithm="ama_fes", max_delay=5))
    assert isinstance(s, strategies.AsyncAMAStrategy)
    assert isinstance(strategies.resolve(FLConfig(algorithm="fedopt")),
                      strategies.FedOptStrategy)
    with pytest.raises(KeyError):
        strategies.get("nope")


def test_no_dispatch_chains_left():
    """Acceptance: algorithm dispatch has exactly one home (the registry)."""
    import repro.core.client
    import repro.core.round
    import repro.core.simulation
    import repro.launch.train
    for mod in (repro.core.round, repro.core.simulation, repro.launch.train,
                repro.core.client):
        with open(mod.__file__) as f:
            assert "fl.algorithm ==" not in f.read(), mod.__name__


# ------------------------------------------- seed equivalence per rule ----

def test_ama_strategy_matches_seed_aggregate():
    rng = np.random.RandomState(0)
    fl = FLConfig(algorithm="ama", alpha0=0.2, eta=1e-3)
    prev, cp = tree(rng), tree(rng, C=4)
    sched = sched_for(rng, 4)
    got, aux = strategies.resolve(fl).aggregate(3, prev, cp, sched, {})
    want = ama_aggregate(fl, 3, prev, cp, sched["data_sizes"],
                         jnp.logical_not(sched["delayed"]))
    assert aux == {}
    assert_trees_close(got, want)


def test_async_ama_strategy_matches_seed_over_rounds():
    rng = np.random.RandomState(1)
    fl = FLConfig(algorithm="ama_fes", max_delay=3, p_delay=0.4)
    strat = strategies.resolve(fl)
    prev_s = prev_m = tree(rng)
    aux = strat.init_state(prev_s)
    queue = aa.init_queue(fl, prev_m)
    for t in range(6):
        cp = tree(rng, C=4)
        sched = sched_for(rng, 4, max_delay=3)
        prev_s, aux = strat.aggregate(t, prev_s, cp, sched, aux)
        queue = aa.enqueue(fl, queue, t, cp, sched["delayed"],
                           sched["delays"])
        prev_m, queue = aa.async_ama_aggregate(
            fl, t, prev_m, cp, sched["data_sizes"],
            jnp.logical_not(sched["delayed"]), queue)
        assert_trees_close(prev_s, prev_m, err_msg=f"round {t}")
    assert_trees_close(aux["queue"], queue)


def test_fedavg_strategy_matches_seed_aggregate():
    rng = np.random.RandomState(2)
    fl = FLConfig(algorithm="fedavg")
    prev, cp = tree(rng), tree(rng, C=4)
    sched = sched_for(rng, 4)
    got, _ = strategies.resolve(fl).aggregate(0, prev, cp, sched, {})
    keep = jnp.logical_and(jnp.logical_not(sched["delayed"]),
                           jnp.logical_not(sched["limited"]))
    want = fedavg_aggregate(prev, cp, sched["data_sizes"], keep)
    assert_trees_close(got, want)


def test_fedprox_strategy_matches_seed_aggregate():
    rng = np.random.RandomState(3)
    fl = FLConfig(algorithm="fedprox")
    prev, cp = tree(rng), tree(rng, C=4)
    sched = sched_for(rng, 4)
    got, _ = strategies.resolve(fl).aggregate(0, prev, cp, sched, {})
    want = fedavg_aggregate(prev, cp, sched["data_sizes"],
                            jnp.logical_not(sched["delayed"]))
    assert_trees_close(got, want)


# ------------------------------------------------ fedopt extension point ----

def test_fedopt_aggregates_and_carries_moments():
    rng = np.random.RandomState(4)
    fl = FLConfig(algorithm="fedopt", server_lr=0.1)
    strat = strategies.resolve(fl)
    prev = tree(rng)
    aux = strat.init_state(prev)
    assert int(aux["step"]) == 0
    p1, aux = strat.aggregate(0, prev, tree(rng, C=4),
                              sched_for(rng, 4), aux)
    p2, aux = strat.aggregate(1, p1, tree(rng, C=4),
                              sched_for(rng, 4), aux)
    assert int(aux["step"]) == 2
    assert any(float(jnp.max(jnp.abs(l))) > 0
               for l in jax.tree.leaves(aux["m"]))
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         p2, prev)
    assert max(jax.tree.leaves(moved)) > 0
    for l in jax.tree.leaves(p2):
        assert np.all(np.isfinite(np.asarray(l)))


def test_fedopt_first_step_is_sign_like_adam():
    """With zero init moments, step 1 is lr * delta/(|delta| + tau) (bias
    correction cancels): bounded by server_lr in magnitude."""
    rng = np.random.RandomState(5)
    fl = FLConfig(algorithm="fedopt", server_lr=0.05)
    strat = strategies.resolve(fl)
    prev = tree(rng)
    cp = tree(rng, C=3)
    sched = {"limited": jnp.zeros((3,), bool),
             "delayed": jnp.zeros((3,), bool),
             "delays": jnp.ones((3,), jnp.int32),
             "data_sizes": jnp.ones((3,), jnp.float32)}
    p1, _ = strat.aggregate(0, prev, cp, sched, strat.init_state(prev))
    step = jax.tree.map(lambda a, b: np.abs(np.asarray(a - b)), p1, prev)
    assert max(float(s.max()) for s in jax.tree.leaves(step)) <= 0.05 + 1e-6


# ----------------------------------------------------- kernel-path parity ----

@pytest.mark.parametrize("algo,md", [("ama", 0), ("ama_fes", 3),
                                     ("fedavg", 0), ("fedopt", 0)])
def test_use_kernel_matches_jnp_path(algo, md):
    rng = np.random.RandomState(6)
    base = dict(algorithm=algo, max_delay=md, p_delay=0.4 if md else 0.0)
    fl_j = FLConfig(**base)
    fl_k = FLConfig(use_kernel=True, **base)
    prev = tree(rng)
    cp = tree(rng, C=3)
    sched = sched_for(rng, 3, max_delay=md)
    sj, sk = strategies.resolve(fl_j), strategies.resolve(fl_k)
    got_j, _ = sj.aggregate(2, prev, cp, sched, sj.init_state(prev))
    got_k, _ = sk.aggregate(2, prev, cp, sched, sk.init_state(prev))
    assert_trees_close(got_k, got_j)


# -------------------------------------------------- fused scan vs loop ----

@pytest.mark.parametrize("algo,md", [("ama_fes", 0), ("ama_fes", 3),
                                     ("fedavg", 0), ("fedprox", 0),
                                     ("fedopt", 0)])
def test_scan_engine_matches_sequential_rounds(algo, md):
    """One lax.scan over 5 rounds == 5 sequential round_step calls."""
    n_rounds, C, steps, b = 5, 2, 2, 4
    model = build_model(ARCHS["paper-cnn"])
    fl = FLConfig(algorithm=algo, max_delay=md, p_delay=0.4 if md else 0.0,
                  lr=0.05)
    rng = np.random.RandomState(7)
    batches = {
        "image": jnp.asarray(rng.randn(n_rounds, C, steps, b, 28, 28, 1),
                             jnp.float32),
        "label": jnp.asarray(rng.randint(0, 10, (n_rounds, C, steps, b)),
                             jnp.int32)}
    scheds = {
        "limited": jnp.asarray(rng.rand(n_rounds, C) < 0.5),
        "delayed": jnp.asarray(rng.rand(n_rounds, C) < (0.4 if md else 0.0)),
        "delays": jnp.asarray(
            rng.randint(1, max(md, 1) + 1, (n_rounds, C)), jnp.int32),
        "data_sizes": jnp.asarray(rng.rand(n_rounds, C) + 0.5, jnp.float32)}

    state0 = init_state(model, fl, jax.random.PRNGKey(0))
    step = jax.jit(make_round_step(model, fl))
    state_seq = state0
    seq_losses = []
    for r in range(n_rounds):
        state_seq, metrics = step(state_seq,
                                  jax.tree.map(lambda x: x[r], batches),
                                  jax.tree.map(lambda x: x[r], scheds))
        seq_losses.append(float(metrics["loss"]))

    loop = make_train_loop(model, fl, per_round_batch=True, donate=False)
    state_scan, metrics = loop(init_state(model, fl, jax.random.PRNGKey(0)),
                               batches, scheds)

    assert int(state_scan["t"]) == n_rounds
    np.testing.assert_allclose(np.asarray(metrics["loss"]),
                               np.asarray(seq_losses), rtol=1e-5, atol=1e-6)
    for g, w in zip(jax.tree.leaves(state_scan["params"]),
                    jax.tree.leaves(state_seq["params"])):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-5)
    for g, w in zip(jax.tree.leaves(state_scan["aux"]),
                    jax.tree.leaves(state_seq["aux"])):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-5)
