"""The unified chunked-scan execution engine (repro.exec).

The safety net for the PR-3 refactor: the fused chunked-scan simulation
must be BIT-IDENTICAL to the per-round-jit fallback (per strategy x per
environment), staging must be pure in the round index (chunking/resume
invariant), the jitted batched eval exact, the full-round-state
checkpoint a bit-identical continuation, and the FL mesh a no-op at
CPU scale.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import (ChunkPrefetcher, build_clients,
                                 stage_chunk, stage_round_indices)
from repro.data.synth import make_image_classification
from repro.exec.evals import Evaluator
from repro.launch.mesh import engine_mesh
from repro.models.api import build_model


@pytest.fixture(scope="module")
def small_world():
    train, test = make_image_classification(n_train=240, n_test=60, seed=0)
    clients = build_clients(train, shard_partition(train["label"], 8, seed=0))
    model = build_model(ARCHS["paper-cnn"])
    return model, train, clients, test


def _fl(**kw):
    base = dict(num_clients=8, clients_per_round=4, local_epochs=1,
                local_batch_size=10, lr=0.1, p_limited=0.25, seed=0)
    base.update(kw)
    return FLConfig(**base)


def assert_states_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------- the equivalence net ----

@pytest.mark.parametrize("env", ["bernoulli", "gilbert_elliott"])
@pytest.mark.parametrize("algo,md", [("ama", 0), ("async_ama", 3),
                                     ("fedavg", 0), ("fedprox", 0),
                                     ("fedopt", 0)])
def test_chunked_scan_bit_identical_to_per_round_loop(small_world, env,
                                                      algo, md):
    """Every registered strategy x {bernoulli, gilbert_elliott}: the
    chunked-scan engine and the --no-scan per-round loop produce
    bit-identical params, aux state AND History."""
    model, _, clients, test = small_world
    fl = _fl(algorithm=algo, env=env, max_delay=md,
             p_delay=0.4 if md else 0.0)
    sims = {s: FederatedSimulation(model, fl, clients, test, use_scan=s)
            for s in (True, False)}
    hists = {s: sim.run(rounds=4, eval_every=2) for s, sim in sims.items()}
    assert_states_identical(sims[True].state, sims[False].state)
    assert hists[True].train_loss == hists[False].train_loss
    assert hists[True].test_acc == hists[False].test_acc
    assert hists[True].test_loss == hists[False].test_loss
    assert len(hists[True].train_loss) == 4
    assert len(hists[True].test_acc) == 2
    assert sims[True].t == 4


# ------------------------------------------------------- data plane ----

def test_stage_chunk_rows_match_per_round_staging(small_world):
    """stage_chunk(t0, n) row i == staging round t0+i alone, and the
    gather reproduces each client's own shard samples."""
    model, train, clients, test = small_world
    sel = np.array([[0, 3, 5], [7, 1, 2], [4, 6, 0], [2, 2, 1]])
    chunk = stage_chunk(train, clients, sel, seed=0, t0=5, steps=3,
                        batch_size=4)
    assert chunk["image"].shape == (4, 3, 3, 4, 28, 28, 1)
    for i in range(4):
        idx = stage_round_indices(clients, sel[i], 0, 5 + i, 3, 4)
        np.testing.assert_array_equal(chunk["image"][i],
                                      train["image"][idx])
        np.testing.assert_array_equal(chunk["label"][i],
                                      train["label"][idx])
        # every drawn index belongs to the client's own shard
        for c in range(3):
            assert set(idx[c].ravel()) <= set(clients[sel[i][c]].indices)


def test_staging_pure_in_t_chunking_invariant(small_world):
    """Staging is keyed on the absolute round index: any chunking of the
    same rounds yields bit-identical batches (the resume guarantee)."""
    model, train, clients, _ = small_world
    sel = np.arange(8).reshape(4, 2) % 8
    whole = stage_chunk(train, clients, sel, seed=3, t0=2, steps=2,
                        batch_size=5)
    parts = [stage_chunk(train, clients, sel[i:i + 1], seed=3, t0=2 + i,
                         steps=2, batch_size=5) for i in range(4)]
    for k in whole:
        np.testing.assert_array_equal(
            whole[k], np.concatenate([p[k] for p in parts]))


def test_chunk_prefetcher_orders_and_propagates_errors():
    out = list(ChunkPrefetcher(lambda x: x * 2, [1, 2, 3, 4]))
    assert out == [2, 4, 6, 8]

    def boom(x):
        if x == 2:
            raise ValueError("staged boom")
        return x

    it = iter(ChunkPrefetcher(boom, [1, 2, 3]))
    assert next(it) == 1
    with pytest.raises(ValueError, match="staged boom"):
        next(it)


def test_chunk_prefetcher_close_releases_worker():
    """An abandoned consumer must not leave the worker parked on a full
    queue holding staged chunks."""
    pf = ChunkPrefetcher(lambda x: x, list(range(10)), depth=1)
    assert next(iter(pf)) == 0
    pf.close()
    pf._thread.join(timeout=5.0)
    assert not pf._thread.is_alive()


def test_engine_rejects_split_data_stores(small_world):
    """The chunked data plane gathers from ONE shared sample store; a
    client built over its own array must be rejected, not silently
    staged from client 0's data."""
    model, train, clients, test = small_world
    other = {k: np.array(v) for k, v in train.items()}
    rogue = build_clients(other, [clients[0].indices])
    with pytest.raises(ValueError, match="shared sample store"):
        FederatedSimulation(model, _fl(), clients[:-1] + rogue, test)


# -------------------------------------------------------- eval layer ----

def test_evaluator_matches_unbatched_reference(small_world):
    model, _, clients, test = small_world
    params = model.init(jax.random.PRNGKey(1))
    acc, loss = Evaluator(model, test, batch_size=512)(params)
    logits, _ = model.forward(params, test)
    lf = np.asarray(logits, np.float64)
    labels = np.asarray(test["label"])
    ref_acc = float(np.mean(np.argmax(lf, -1) == labels))
    logz = np.log(np.sum(np.exp(lf - lf.max(-1, keepdims=True)), -1)) \
        + lf.max(-1)
    ref_loss = float(np.mean(logz - lf[np.arange(len(labels)), labels]))
    assert acc == pytest.approx(ref_acc, abs=1e-6)
    assert loss == pytest.approx(ref_loss, rel=1e-5)


def test_evaluator_batch_split_invariant(small_world):
    """Sum-based accumulation: accuracy/loss independent of the batch
    split (incl. a split that needs wrap-padding)."""
    model, _, clients, test = small_world
    params = model.init(jax.random.PRNGKey(2))
    a1, l1 = Evaluator(model, test, batch_size=512)(params)
    a2, l2 = Evaluator(model, test, batch_size=17)(params)
    assert a1 == pytest.approx(a2, abs=1e-6)
    assert l1 == pytest.approx(l2, rel=1e-5)


# ------------------------------------------------- checkpoint / resume ----

@pytest.mark.parametrize("algo,md", [("async_ama", 3), ("fedopt", 0)])
def test_save_restore_continue_bit_identical(small_world, tmp_path, algo,
                                             md):
    """Full round-state checkpoint {params, t, aux} (ring buffer /
    fedopt moments): save -> restore -> continue == uninterrupted run,
    bit-identically, even across different chunk boundaries."""
    model, _, clients, test = small_world
    fl = _fl(algorithm=algo, max_delay=md, p_delay=0.4 if md else 0.0)
    path = str(tmp_path / "state.npz")

    full = FederatedSimulation(model, fl, clients, test)
    hist_full = full.run(rounds=5, eval_every=2)

    part = FederatedSimulation(model, fl, clients, test)
    part.run(rounds=3, eval_every=2)
    part.save(path)

    cont = FederatedSimulation(model, fl, clients, test)
    cont.resume(path)
    assert cont.t == 3
    hist_cont = cont.run(rounds=2, eval_every=2)

    assert_states_identical(full.state, cont.state)
    assert hist_full.train_loss[3:] == hist_cont.train_loss
    # chunk boundaries sit on ABSOLUTE multiples of eval_every: the
    # resumed run evaluates at the same global rounds (here t=4) and
    # sees the same metrics as the uninterrupted run
    assert hist_cont.test_acc == hist_full.test_acc[1:]
    assert hist_cont.test_loss == hist_full.test_loss[1:]


# ------------------------------------------------------------ sharding ----

def test_engine_under_fl_mesh_bit_identical(small_world):
    """engine_mesh re-views whatever devices exist as (client, dsub,
    model); at CPU scale the constraints are degenerate and the result
    bit-identical to the mesh-free run."""
    model, _, clients, test = small_world
    mesh = engine_mesh(4)
    assert tuple(mesh.axis_names) == ("client", "dsub", "model")
    fl = _fl(algorithm="ama")
    plain = FederatedSimulation(model, fl, clients, test)
    meshed = FederatedSimulation(model, fl, clients, test, mesh=mesh)
    plain.run(rounds=2)
    meshed.run(rounds=2)
    assert_states_identical(plain.state, meshed.state)


# -------------------------------------------------------- public API ----

def test_run_round_and_eval_compat(small_world):
    """The legacy surface survives: run_round advances one round,
    evaluate returns (acc, loss), params/t/aux mirror the state."""
    model, _, clients, test = small_world
    sim = FederatedSimulation(model, _fl(), clients, test)
    tl = sim.run_round()
    assert np.isfinite(tl) and sim.t == 1
    acc, loss = sim.evaluate()
    assert 0.0 <= acc <= 1.0 and np.isfinite(loss)
    assert sim.params is sim.state["params"]
    assert sim.aux == sim.state["aux"]
