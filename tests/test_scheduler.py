"""Heterogeneity scheduler: batch(t0, n) must be bit-identical to n
sequential round(t) calls (the contract the fused scan engine rides on),
and the dead RNG state stays dead."""
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.scheduler import HeterogeneitySchedule


@pytest.mark.parametrize("t0,n", [(0, 1), (0, 5), (7, 8), (123, 17)])
@pytest.mark.parametrize("p_delay,max_delay", [(0.0, 0), (0.4, 5)])
def test_batch_rows_bit_identical_to_sequential_rounds(t0, n, p_delay,
                                                       max_delay):
    fl = FLConfig(num_clients=20, clients_per_round=6, p_limited=0.3,
                  p_delay=p_delay, max_delay=max_delay, seed=3)
    sched = HeterogeneitySchedule(fl)
    got = sched.batch(t0, n)
    assert got["selected"].shape == (n, fl.clients_per_round)
    for i in range(n):
        rs = sched.round(t0 + i)
        np.testing.assert_array_equal(got["selected"][i], rs.selected)
        np.testing.assert_array_equal(got["limited"][i], rs.limited)
        np.testing.assert_array_equal(got["delayed"][i], rs.delayed)
        np.testing.assert_array_equal(got["delays"][i], rs.delays)


def test_batch_independent_of_batching_layout():
    """Property behind the bit-identity: round t's schedule is a pure
    function of (seed, t), however the rounds are chunked."""
    fl = FLConfig(num_clients=10, clients_per_round=4, p_delay=0.5,
                  max_delay=3, seed=11)
    sched = HeterogeneitySchedule(fl)
    whole = sched.batch(0, 12)
    split = {k: np.concatenate([sched.batch(0, 5)[k], sched.batch(5, 7)[k]])
             for k in whole}
    for k in whole:
        np.testing.assert_array_equal(whole[k], split[k])


def test_dead_rng_removed():
    sched = HeterogeneitySchedule(FLConfig())
    assert not hasattr(sched, "_rng")


def test_no_delay_config_emits_unit_delays():
    fl = FLConfig(num_clients=8, clients_per_round=4, p_delay=0.0,
                  max_delay=0)
    got = HeterogeneitySchedule(fl).batch(0, 4)
    assert not got["delayed"].any()
    np.testing.assert_array_equal(got["delays"],
                                  np.ones((4, 4), np.int32))
