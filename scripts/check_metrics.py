"""CI gate: validate a --metrics-out JSONL against the telemetry schema.

Exit 0 when every row conforms (header with a supported schema version,
known row kinds, required keys, monotone round indices, evals aligned
to logged rounds); exit 1 with one line per violation otherwise. Run in
CI right after the launcher smoke so a PR that silently breaks the
metrics schema (or stops emitting a series the report CLI consumes)
cannot land green.

Usage:  PYTHONPATH=src python scripts/check_metrics.py run.jsonl [...]
        ... check_metrics.py --require-extended run.jsonl   # round rows
        must carry the extended series (staleness/mix/norm/wire)
        ... check_metrics.py --require-serve serve.jsonl    # serving
        runs: per-request serve rows (with latency series) + one
        serve_summary row must be present
        ... check_metrics.py --require-comm run.jsonl       # comm-plane
        runs: round rows must carry the compressed-wire fields with an
        actual compression (ratio > 1)
        ... check_metrics.py --json out.json run.jsonl      # also write
        the violations as a findings JSON artifact (the same
        ``repro.analysis.findings`` schema fedlint emits, so one CI
        consumer parses every gate)
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.findings import Finding, write_json
from repro.obs.log import read_rows, validate_rows
from repro.obs.metrics import ROUND_METRIC_KEYS


def check(path: str, require_extended: bool = False,
          require_serve: bool = False,
          require_comm: bool = False) -> list[str]:
    try:
        rows = read_rows(path)
    except (OSError, ValueError) as e:
        return [str(e)]
    errs = validate_rows(rows)
    rnd = [r for r in rows if r.get("kind") == "round"]
    if require_comm:
        if not rnd:
            errs.append("no round rows")
        for k in ("bytes_on_wire_compressed", "compression_ratio"):
            missing = sum(1 for r in rnd if k not in r)
            if missing:
                errs.append(f"comm series {k!r} missing from "
                            f"{missing}/{len(rnd)} round rows")
        uncompressed = sum(
            1 for r in rnd
            if isinstance(r.get("compression_ratio"), (int, float))
            and r["compression_ratio"] <= 1.0)
        if rnd and uncompressed == len(rnd):
            errs.append("compression_ratio <= 1.0 on every round row — "
                        "the comm plane is not actually compressing")
    if require_extended:
        if not rnd:
            errs.append("no round rows")
        for k in ROUND_METRIC_KEYS:
            missing = sum(1 for r in rnd if k not in r)
            if missing:
                errs.append(f"extended series {k!r} missing from "
                            f"{missing}/{len(rnd)} round rows")
    if require_serve:
        from repro.obs.log import SERVE_LATENCY_KEYS
        srv = [r for r in rows if r.get("kind") == "serve"]
        summ = [r for r in rows if r.get("kind") == "serve_summary"]
        if not srv:
            errs.append("no serve rows")
        if len(summ) != 1:
            errs.append(f"expected exactly 1 serve_summary row, "
                        f"got {len(summ)}")
        for k in SERVE_LATENCY_KEYS:
            missing = sum(1 for r in srv if k not in r)
            if missing:
                errs.append(f"latency series {k!r} missing from "
                            f"{missing}/{len(srv)} serve rows")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+")
    ap.add_argument("--require-extended", action="store_true",
                    help="fail unless round rows carry the extended "
                         "telemetry series")
    ap.add_argument("--require-serve", action="store_true",
                    help="fail unless per-request serve rows and one "
                         "serve_summary row are present")
    ap.add_argument("--require-comm", action="store_true",
                    help="fail unless round rows carry the comm-plane "
                         "wire fields (bytes_on_wire_compressed, "
                         "compression_ratio) with ratio > 1")
    ap.add_argument("--json", dest="json_out", default=None, metavar="PATH",
                    help="also write the violations as a findings JSON "
                         "artifact (repro.analysis.findings schema)")
    args = ap.parse_args(argv)
    failed = False
    findings = []
    for path in args.paths:
        errs = check(path, args.require_extended, args.require_serve,
                     args.require_comm)
        if errs:
            failed = True
            for e in errs:
                print(f"{path}: {e}")
            findings.extend(Finding(rule="METRICS", path=path, line=0,
                                    message=e) for e in errs)
        else:
            rows = read_rows(path)
            n_round = sum(r.get("kind") == "round" for r in rows)
            n_eval = sum(r.get("kind") == "eval" for r in rows)
            n_serve = sum(r.get("kind") == "serve" for r in rows)
            extra = f", {n_serve} serve rows" if n_serve else ""
            print(f"{path}: OK ({n_round} round rows, {n_eval} evals"
                  f"{extra})")
    if args.json_out:
        write_json(args.json_out, "check_metrics", findings)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
