"""CI benchmark-regression gate.

Re-runs the smoke configuration of each gated benchmark and fails (exit
1) if its fused/scan throughput ratio drops below 0.9x the committed
``BENCH_*.json`` baseline, so a PR that quietly un-fuses the scan engine
or the server plane cannot land green. The committed baseline is the
JSON's ``smoke.gate`` value — the smoke-scale speedup discounted for
shared-runner variance (~±20% on wall-clock ratios at these sizes), so
the gate trips on real regressions (2-10x fusion losses), not jitter.

Fresh smoke results are written as JSON next to the baselines (or into
``--out-dir``) for upload as workflow artifacts. On a regression the
report includes the provenance diff (jax version, backend, device
count, git sha — ``repro.obs.provenance``) between the committed
baseline and the fresh run, so "what regressed" distinguishes an engine
change from an environment change at a glance.

Usage:  PYTHONPATH=src python scripts/check_bench.py [--out-dir DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "benchmarks"))

FACTOR = 0.9

#: benchmark module -> (baseline json, fresh-run metric, baseline gate key)
GATES = {
    "sim_engine": ("BENCH_sim_engine.json",
                   lambda rec: rec["speedup"],
                   lambda base: base["smoke"]["gate"]),
    "server_plane": ("BENCH_server_plane.json",
                     lambda rec: rec["geomean_speedup"],
                     lambda base: base["smoke"]["gate"]),
    "client_plane": ("BENCH_client_plane.json",
                     lambda rec: rec["speedup"],
                     lambda base: base["smoke"]["gate"]),
    # scale_ratio = rounds/sec at K=1e6 over K=1e3 (~1.0 when per-round
    # scheduling+staging is population-free); an O(K) regression in the
    # virtual-population path drags it toward 0 and trips the gate
    "federation_scale": ("BENCH_federation_scale.json",
                         lambda rec: rec["scale_ratio"],
                         lambda base: base["smoke"]["gate"]),
    # paged continuous-batching engine vs seed per-token loop on the
    # mixed-prompt-length mixture; a regression means chunked prefill
    # or the decode bursts fell back to per-token dispatch
    "serve_plane": ("BENCH_serve_plane.json",
                    lambda rec: rec["speedup"],
                    lambda base: base["smoke"]["gate"]),
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(ROOT, "bench-fresh"),
                    help="where fresh smoke JSONs go (workflow artifacts)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    failures = []
    for name, (baseline_file, fresh_metric, base_gate) in GATES.items():
        path = os.path.join(ROOT, baseline_file)
        with open(path) as f:
            baseline = json.load(f)
        print(f"--- {name}: smoke run (baseline {baseline_file}) ---")
        mod = __import__(name)
        rec = mod.run(smoke=True)
        out = os.path.join(args.out_dir, f"BENCH_{name}_smoke.json")
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
            f.write("\n")
        fresh = fresh_metric(rec)
        floor = FACTOR * base_gate(baseline)
        verdict = "OK" if fresh >= floor else "REGRESSION"
        print(f"{name}: fresh speedup {fresh:.3f} vs floor {floor:.3f} "
              f"(0.9 x committed gate) -> {verdict}")
        if fresh < floor:
            failures.append(name)
            # environment-or-code triage: baselines committed before the
            # provenance stamp existed just report "no baseline stamp"
            from repro.obs.provenance import diff as prov_diff
            pd = prov_diff(baseline.get("provenance"),
                           rec.get("provenance"))
            if baseline.get("provenance") is None:
                print(f"{name}: baseline has no provenance stamp "
                      f"(pre-telemetry BENCH json); fresh env: "
                      f"{rec.get('provenance')}")
            elif pd:
                print(f"{name}: provenance diff baseline -> fresh: "
                      + "; ".join(pd))
            else:
                print(f"{name}: provenance identical to baseline — "
                      f"regression is in the code path, not the env")

    if failures:
        print(f"benchmark regression gate FAILED: {failures} — fused/scan "
              f"throughput dropped below 0.9x the committed baseline "
              f"(re-baseline BENCH_*.json only with a justified perf "
              f"change)")
        return 1
    print("benchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
