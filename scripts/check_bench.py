"""CI benchmark-regression gate, driven by scripts/bench_gates.json.

Re-runs the smoke configuration of each benchmark registered in the
manifest and applies its declarative checks: each check compares a
dotted-path metric of the FRESH ``run(smoke=True)`` record against
``factor x`` a dotted-path value of the committed ``BENCH_*.json``
baseline —

  * direction "min": fresh must stay ABOVE the scaled baseline
    (throughput floors; a PR that quietly un-fuses the scan engine or
    the server plane cannot land green), the default factor 0.9
    discounting shared-runner wall-clock jitter so the gate trips on
    real regressions (2-10x fusion losses), not noise;
  * direction "max": fresh must stay BELOW it (resource ceilings — the
    comm plane's bytes-on-wire: a compression regression fails CI the
    same way a speed regression does).

Fresh smoke results are written as JSON next to the baselines (or into
``--out-dir``) for upload as workflow artifacts. For EVERY failed gate
the report includes the provenance diff (jax version, backend, device
count, git sha — ``repro.obs.provenance``) between the committed
baseline and the fresh run, so "what regressed" distinguishes an engine
change from an environment change at a glance.

Adding a gated benchmark is a manifest edit, not code: register the
module + baseline + checks in ``bench_gates.json``.

Usage:  PYTHONPATH=src python scripts/check_bench.py [--out-dir DIR]
        ... check_bench.py --only comm_plane   # a single gate
        ... check_bench.py --json out.json     # also write the failed
        gates as a findings JSON artifact (the same
        ``repro.analysis.findings`` schema fedlint emits)
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "benchmarks"))

from repro.analysis.findings import Finding, write_json  # noqa: E402

MANIFEST = os.path.join(ROOT, "scripts", "bench_gates.json")


def lookup(record: dict, path: str):
    """Dotted-path lookup: 'smoke.gate' -> record['smoke']['gate']."""
    cur = record
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(f"path {path!r} missing at {part!r}")
        cur = cur[part]
    return cur


def check_one(name: str, spec: dict, default_factor: float, rec: dict,
              baseline: dict) -> list[tuple[str, str]]:
    """Apply one benchmark's checks; returns (label, detail) failures."""
    fails = []
    for chk in spec["checks"]:
        fresh = float(lookup(rec, chk["metric"]))
        base = float(lookup(baseline, chk["against"]))
        factor = float(chk.get("factor", default_factor))
        bound = factor * base
        direction = chk["direction"]
        if direction == "min":
            ok, rel = fresh >= bound, "floor"
        elif direction == "max":
            ok, rel = fresh <= bound, "ceiling"
        else:
            raise ValueError(f"{name}: unknown direction {direction!r}")
        verdict = "OK" if ok else "REGRESSION"
        print(f"{name}: {chk['metric']} {fresh:.3f} vs {rel} {bound:.3f} "
              f"({factor:g} x baseline {chk['against']}) -> {verdict}")
        if not ok:
            fails.append((f"{name}.{chk['metric']} ({direction} check)",
                          f"{chk['metric']} {fresh:.3f} crossed its {rel} "
                          f"{bound:.3f} ({factor:g} x baseline "
                          f"{chk['against']} = {base:.3f})"))
    return fails


def provenance_triage(name: str, baseline: dict, rec: dict) -> None:
    """Environment-or-code triage, printed for EVERY failed gate."""
    from repro.obs.provenance import diff as prov_diff
    if baseline.get("provenance") is None:
        # baselines committed before the provenance stamp existed
        print(f"{name}: baseline has no provenance stamp (pre-telemetry "
              f"BENCH json); fresh env: {rec.get('provenance')}")
        return
    pd = prov_diff(baseline.get("provenance"), rec.get("provenance"))
    if pd:
        print(f"{name}: provenance diff baseline -> fresh: "
              + "; ".join(pd))
    else:
        print(f"{name}: provenance identical to baseline — regression "
              f"is in the code path, not the env")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(ROOT, "bench-fresh"),
                    help="where fresh smoke JSONs go (workflow artifacts)")
    ap.add_argument("--only", default=None,
                    help="run a single gate from the manifest")
    ap.add_argument("--manifest", default=MANIFEST)
    ap.add_argument("--json", dest="json_out", default=None, metavar="PATH",
                    help="also write the failed gates as a findings JSON "
                         "artifact (repro.analysis.findings schema)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    with open(args.manifest) as f:
        manifest = json.load(f)
    default_factor = float(manifest.get("default_factor", 0.9))
    gates = manifest["gates"]
    if args.only:
        if args.only not in gates:
            print(f"unknown gate {args.only!r}; manifest has: "
                  f"{sorted(gates)}")
            return 2
        gates = {args.only: gates[args.only]}

    failures, findings = [], []
    for name, spec in gates.items():
        path = os.path.join(ROOT, spec["baseline"])
        with open(path) as f:
            baseline = json.load(f)
        print(f"--- {name}: smoke run (baseline {spec['baseline']}) ---")
        mod = __import__(name)
        rec = mod.run(smoke=True)
        out = os.path.join(args.out_dir, f"BENCH_{name}_smoke.json")
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
            f.write("\n")
        fails = check_one(name, spec, default_factor, rec, baseline)
        if fails:
            failures.extend(label for label, _ in fails)
            findings.extend(
                Finding(rule="BENCH-REGRESSION", path=spec["baseline"],
                        line=0, message=detail) for _, detail in fails)
            provenance_triage(name, baseline, rec)

    if args.json_out:
        write_json(args.json_out, "check_bench", findings)
    if failures:
        print(f"benchmark regression gate FAILED: {failures} — a gated "
              f"metric crossed its manifest bound (re-baseline "
              f"BENCH_*.json only with a justified perf/size change)")
        return 1
    print("benchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
