"""Production mesh definitions (TPU v5e pods).

``make_production_mesh`` is the canonical entry point (spec'd shape/axes).
The federated TRAIN mesh is a re-view of the same devices as
("client", "dsub", "model"): C client cohorts x FSDP x tensor-parallel.
On the multi-pod mesh the pod axis folds into the client axis — each pod
hosts client cohorts and the AMA aggregation is the only cross-pod
collective (the paper's communication pattern).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def engine_mesh(cohorts: int = 0) -> Mesh:
    """The execution engine's FL mesh over WHATEVER devices exist.

    On a real pod (>= 256 devices) this is ``fl_view`` of the production
    mesh; elsewhere it re-views the available devices as
    ("client", "dsub", "model") with the widest client axis that divides
    both the device count and ``cohorts`` (so the stacked-client-axis
    constraints actually apply). On this CPU container that is a
    (1, 1, 1) mesh — the identical program, degenerate shardings — which
    is exactly what lets one engine serve paper scale and pod scale.
    """
    devices = np.asarray(jax.devices())
    n = devices.size
    if n >= 256:
        return fl_view(make_production_mesh(), cohorts or 4)
    client = 1
    for d in range(1, n + 1):
        if n % d == 0 and (cohorts <= 0 or cohorts % d == 0):
            client = d
    return Mesh(devices.reshape(client, n // client, 1),
                ("client", "dsub", "model"))


def fl_view(mesh: Mesh, cohorts: int, expert_parallel: int = 0,
            model_width: int = 0) -> Mesh:
    """("client", "dsub", "model") view of a production mesh.

    Single-pod (16, 16): client x dsub factorise the 16-wide data axis.
    Multi-pod (2, 16, 16): the pod axis multiplies the client axis, i.e.
    2*cohorts client groups, cross-pod traffic only at aggregation.

    expert_parallel > 0 factorises the model axis into
    ("expert", "etp") = (E, model/E) for MoE archs whose expert count does
    not equal the model-axis width: experts live on their own sub-axis and
    tensor-parallel runs within each expert (§Perf H1). Dense params then
    shard over the tuple ("expert", "etp") == the whole model axis.
    """
    devices = np.asarray(mesh.devices)
    if devices.ndim == 3:                       # (pod, data, model)
        n_pod, n_data, n_model = devices.shape
        n_client = n_pod * cohorts
        dsub = n_data // cohorts
        if dsub * cohorts != n_data:
            raise ValueError(f"cohorts={cohorts} must divide data={n_data}")
    else:                                       # (data, model)
        n_data, n_model = devices.shape
        n_client = cohorts
        dsub = n_data // cohorts
        if dsub * cohorts != n_data:
            raise ValueError(f"cohorts={cohorts} must divide data={n_data}")
    if model_width and model_width != n_model:
        # per-arch TP width (e.g. 8 so rwkv6's 40 heads shard evenly);
        # the freed factor widens FSDP. Total devices unchanged. On small
        # test meshes that can't honour the width, keep the default.
        total = dsub * n_model
        if total % model_width == 0 and total >= model_width:
            dsub, n_model = total // model_width, model_width
    if expert_parallel and n_model % expert_parallel == 0 \
            and expert_parallel < n_model:
        dv = devices.reshape(n_client, dsub, expert_parallel,
                             n_model // expert_parallel)
        return Mesh(dv, ("client", "dsub", "expert", "etp"))
    dv = devices.reshape(n_client, dsub, n_model)
    return Mesh(dv, ("client", "dsub", "model"))


def serve_view(mesh: Mesh, expert_parallel: int = 0) -> Mesh:
    """("data", "model") view (folds the pod axis into data if present)."""
    devices = np.asarray(mesh.devices)
    if devices.ndim == 3:
        p, d, m = devices.shape
        devices = devices.reshape(p * d, m)
    n_data, n_model = devices.shape
    if expert_parallel and n_model % expert_parallel == 0 \
            and expert_parallel < n_model:
        dv = devices.reshape(n_data, expert_parallel,
                             n_model // expert_parallel)
        return Mesh(dv, ("data", "expert", "etp"))
    return Mesh(devices, ("data", "model"))
