"""Serving launcher: a thin front over the serving engines.

  python -m repro.launch.serve --arch minitron-8b --reduced --tokens 16
  python -m repro.launch.serve --arch minitron-8b --reduced \
      --engine paged --prompt-mix 6x2,20x2 --max-batch-tokens 256 \
      --metrics-out serve.jsonl

Engines (src/repro/serve/):
  loop   lockstep per-token decode with per-request prompt lengths
         (padded positions never enter the KV cache); with
         --prefill-chunk > 0 the shared prompt prefix is prefilled in
         jitted chunks, bit-identically to the per-token path.
  paged  continuous batching over a shared paged KV pool: FIFO
         token-budget admission (--max-batch-tokens), per-request block
         tables, chunked prefill straight into the pool.

Workload: either a uniform batch (--batch x --prompt-len), a mixture
(--prompt-mix "LENxCOUNT,..."), or a request trace (--trace, JSONL rows
{"id": int, "prompt_len": int | "prompt": [ids], "max_new": int}).

--metrics-out streams schema-versioned serving telemetry (one "serve"
row per request: queue/prefill/decode seconds; one "serve_summary" row:
tokens/sec + p50/p95/p99) through obs.log.MetricsLogger — validated by
scripts/check_metrics.py --require-serve.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import restore_params
from repro.configs.base import reduced
from repro.configs.registry import serving_config
from repro.models.api import build_model
from repro.obs.timing import profile_trace, sync_time
from repro.serve import LoopEngine, PagedEngine, Request


def batched_decode(model, params, prompts, max_new: int, max_len: int,
                   lengths=None):
    """prompts: (B, P) int32. Greedy decode max_new tokens.

    ``lengths`` (optional, (B,) ints) gives each row's REAL prompt
    length; rows are right-padded to P but padded positions never enter
    the KV cache — each row decodes from its own length. Without it
    every row is taken at full length P (the seed behaviour for
    uniform batches). Returns (B, P + max_new) int32.
    """
    assert prompts.ndim == 2 and prompts.shape[1] >= 1, \
        f"prompts must be (B, P>=1) int32, got {prompts.shape}"
    B, P = prompts.shape
    lens = [int(x) for x in (lengths if lengths is not None
                             else [P] * B)]
    host = np.asarray(prompts)
    reqs = [Request(rid=b, prompt=host[b, :lens[b]].tolist(),
                    max_new=max_new) for b in range(B)]
    results = LoopEngine(model, params).run(reqs)
    out = np.asarray(prompts).copy()
    gen = np.zeros((B, max_new), np.int32)
    for b, r in enumerate(results):
        gen[b] = r["tokens"][lens[b]:lens[b] + max_new]
    return jnp.concatenate([jnp.asarray(out), jnp.asarray(gen)], axis=1)


def _mixture_requests(spec: str, max_new: int, vocab: int, seed: int = 0):
    """'8x4,24x2' -> 4 prompts of len 8 + 2 of len 24 (random tokens)."""
    rng = np.random.RandomState(seed)
    reqs, rid = [], 0
    for part in spec.split(","):
        ln, cnt = (int(v) for v in part.strip().split("x"))
        for _ in range(cnt):
            reqs.append(Request(
                rid=rid, max_new=max_new,
                prompt=rng.randint(1, vocab, (ln,)).tolist()))
            rid += 1
    return reqs


def _trace_requests(path: str, max_new: int, vocab: int):
    rng = np.random.RandomState(0)
    reqs = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            prompt = row.get("prompt")
            if prompt is None:
                prompt = rng.randint(
                    1, vocab, (int(row["prompt_len"]),)).tolist()
            reqs.append(Request(rid=int(row.get("id", i)), prompt=prompt,
                                max_new=int(row.get("max_new", max_new))))
    return reqs


def build_engine(model, params, args):
    if args.engine == "paged":
        return PagedEngine(model, params, max_slots=args.max_slots,
                           block_size=args.block_size,
                           max_batch_tokens=args.max_batch_tokens,
                           prefill_chunk=args.prefill_chunk)
    return LoopEngine(model, params, prefill_chunk=args.prefill_chunk)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", choices=("loop", "paged"), default="loop")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--prompt-mix", default=None, metavar="LxN,...",
                    help='mixed prompt lengths, e.g. "8x4,24x2"')
    ap.add_argument("--trace", default=None, metavar="JSONL",
                    help="request trace: rows with id/prompt_len|prompt/"
                         "max_new")
    ap.add_argument("--tokens", type=int, default=16,
                    help="max_new per request (trace rows may override)")
    ap.add_argument("--max-batch-tokens", type=int, default=0,
                    help="paged: in-flight sum(prompt+max_new) budget "
                         "(0 = unbounded)")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="chunked-prefill width (loop: 0 = per-token)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--metrics-out", default=None, metavar="JSONL")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap serving in jax.profiler.trace(DIR)")
    args = ap.parse_args(argv)

    cfg = serving_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.checkpoint:
        # accepts bare params files AND the {params, t, aux} round-state
        # files the trainer's --checkpoint writes (params subtree sliced)
        params = restore_params(args.checkpoint, params)
        print(f"restored {args.checkpoint}")

    if args.trace:
        reqs = _trace_requests(args.trace, args.tokens, cfg.vocab_size)
    elif args.prompt_mix:
        reqs = _mixture_requests(args.prompt_mix, args.tokens,
                                 cfg.vocab_size)
    else:
        reqs = _mixture_requests(f"{args.prompt_len}x{args.batch}",
                                 args.tokens, cfg.vocab_size)

    engine = build_engine(model, params, args)
    with profile_trace(args.profile):
        dt, results = sync_time(engine.run, reqs)
    summary = engine.last_summary

    if args.metrics_out:
        from repro.obs.log import MetricsLogger
        with MetricsLogger(args.metrics_out) as log:
            log.header(extra={"serve": {
                "arch": args.arch, "engine": args.engine,
                "requests": len(reqs),
                "max_batch_tokens": args.max_batch_tokens,
                "max_slots": args.max_slots,
                "block_size": args.block_size,
                "prefill_chunk": args.prefill_chunk}})
            for r in results:
                log.serve(r)
            log.serve_summary(summary)
        print(f"wrote {args.metrics_out}")

    print(f"engine={args.engine} served {summary['requests']} requests, "
          f"{summary['new_tokens']} new tokens in {dt:.2f}s "
          f"({summary['tokens_per_s']} tok/s, p50 {summary['p50_ms']}ms "
          f"p95 {summary['p95_ms']}ms p99 {summary['p99_ms']}ms)")
    print("sample:", results[0]["tokens"][:24])


if __name__ == "__main__":
    main()
