"""Serving launcher: batched greedy decoding of the (federated) global
model with a KV cache — the deployment half of the framework.

  python -m repro.launch.serve --arch minitron-8b --reduced --tokens 16
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import restore_params
from repro.configs.base import reduced
from repro.configs.registry import serving_config
from repro.models.api import build_model
from repro.obs.timing import annotate, profile_trace, sync_time


def batched_decode(model, params, prompts, max_new: int, max_len: int):
    """prompts: (B, P) int32. Greedy decode max_new tokens."""
    cfg = model.cfg
    assert prompts.ndim == 2 and prompts.shape[1] >= 1, \
        f"prompts must be (B, P>=1) int32, got {prompts.shape}"
    B, P = prompts.shape
    if cfg.family == "audio":
        fe = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        cache = model.init_decode_cache(params, fe, max_len)
    else:
        cache = model.init_decode_cache(params, B, max_len)
    step = jax.jit(model.decode_step)
    # prefill token-by-token (teacher forcing: only the cache matters)
    with annotate("prefill"):
        for t in range(P - 1):
            _, cache = step(params, prompts[:, t],
                            jnp.full((B,), t, jnp.int32), cache)
    out = [prompts]
    tok = prompts[:, -1]
    with annotate("decode"):
        for t in range(P - 1, P - 1 + max_new):
            logits, cache = step(params, tok,
                                 jnp.full((B,), t, jnp.int32), cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok[:, None])
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap decoding in jax.profiler.trace(DIR) with "
                         "named prefill/decode regions")
    args = ap.parse_args()

    cfg = serving_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.checkpoint:
        # accepts bare params files AND the {params, t, aux} round-state
        # files the trainer's --checkpoint writes (params subtree sliced)
        params = restore_params(args.checkpoint, params)
        print(f"restored {args.checkpoint}")
    rng = np.random.RandomState(0)
    prompts = jnp.asarray(
        rng.randint(1, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    # obs.timing.sync_time: perf_counter + block_until_ready on the
    # decoded tokens — the seed's time.time() span closed while the
    # final decode steps were still in flight, inflating tok/s
    with profile_trace(args.profile):
        dt, out = sync_time(batched_decode, model, params, prompts,
                            args.tokens,
                            args.prompt_len + args.tokens + 1)
    n_new = args.batch * args.tokens
    print(f"decoded {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s on CPU)")
    print("sample:", np.asarray(out[0])[:24].tolist())


if __name__ == "__main__":
    main()
