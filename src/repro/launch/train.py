"""Federated training launcher.

Two configurations of ONE execution engine (``repro.exec``):
  * paper scale (default): K simulated clients — exactly the paper's §V
    experiment with all heterogeneity knobs. The run is driven in
    ``--eval-every``-round chunks through the fused ``lax.scan`` engine
    (batches for a whole chunk staged in one gather, next chunk
    prefetched host-side while the device runs).
  * --pod: C cohorts over the FL mesh view. The WHOLE run is one fused
    ``lax.scan`` program — one compile, zero per-round dispatch.

``--no-scan`` falls back to the bit-identical per-round-jit loop at
either scale (the configuration the engine benchmarks compare against).
Both scales run under ``launch.mesh.engine_mesh``: on this CPU container
that is a degenerate (1, 1, 1) mesh; on a v5e pod the identical program
spans 256 chips with the stacked client axis sharded.

``--checkpoint`` saves and ``--resume`` restores the FULL round state
{params, t, aux} (async ring buffer, fedopt moments), so continuation
is bit-identical to an uninterrupted run.

``--algorithm`` accepts any name in the server-strategy registry
(repro.core.strategies); ``--env`` any name in the environment registry
(repro.env: bernoulli / gilbert_elliott / bandwidth / trace) and
``--scenario`` any named environment + config binding
(repro.env.scenarios) — adding a strategy/environment/scenario file
extends this launcher with no edits here.

``--metrics-out run.jsonl`` switches on the telemetry plane
(``repro.obs``): per-round staleness/participation/mix/norm/wire series
as schema-versioned JSONL plus a phase-time summary (summarize with
``python -m repro.obs.report run.jsonl``); ``--profile DIR`` wraps the
run in a ``jax.profiler`` trace with named chunk/eval regions.

Examples:
  python -m repro.launch.train --arch paper-cnn --rounds 60 --p-limited 0.5
  python -m repro.launch.train --algorithm fedopt --rounds 5 --eval-every 5
  python -m repro.launch.train --scenario bursty --rounds 40
  python -m repro.launch.train --rounds 20 --checkpoint ck.npz
  python -m repro.launch.train --rounds 20 --resume ck.npz
  python -m repro.launch.train --arch minitron-8b --pod --rounds 3 --reduced
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import env as env_mod
from repro.checkpoint.io import restore_state, save_state
from repro.configs.base import FLConfig, reduced
from repro.configs.registry import (environment_names, get_arch,
                                    get_scenario, scenario_names)
from repro.core import strategies
from repro.core.round import init_state
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import VirtualClientShards, build_clients
from repro.env.virtual import is_virtual
from repro.data.synth import make_image_classification, make_lm_tokens
from repro.exec import ChunkRunner
from repro.launch.mesh import engine_mesh
from repro.models.api import build_model
from repro.obs.log import MetricsLogger
from repro.obs.metrics import payload_bytes
from repro.obs.timing import profile_trace, sync_time


def _logger(args) -> MetricsLogger | None:
    return MetricsLogger(args.metrics_out) if args.metrics_out else None


def _print_phases(timer) -> None:
    summary = timer.summary()
    if summary:
        print("phases: " + "  ".join(
            f"{k}={v['seconds']:.2f}s/{v['calls']}"
            for k, v in summary.items()))


def paper_scale(args, fl: FLConfig):
    model = build_model(get_arch(args.arch))
    train, test = make_image_classification(
        n_train=args.n_train, n_test=400, seed=fl.seed)
    if is_virtual(fl):
        # virtual population: clients are arithmetic shard views of the
        # base store — nothing materialised per client, any K
        clients = VirtualClientShards(
            train, fl.num_clients,
            shard_size=max(fl.local_batch_size,
                           args.n_train // min(fl.num_clients, 64)),
            seed=fl.seed)
    else:
        clients = build_clients(
            train,
            shard_partition(train["label"], fl.num_clients, seed=fl.seed))
    logger = _logger(args)
    sim = FederatedSimulation(model, fl, clients, test,
                              use_scan=not args.no_scan,
                              mesh=engine_mesh(fl.clients_per_round),
                              logger=logger)
    if args.resume:
        sim.resume(args.resume)
        print(f"resumed {args.resume} at round {sim.t}")
    with profile_trace(args.profile):
        hist = sim.run(rounds=args.rounds, eval_every=args.eval_every,
                       verbose=True)
    print(f"final: acc={hist.final_accuracy():.4f} "
          f"stability_var={hist.stability_variance():.3f}")
    _print_phases(sim.timer)
    if args.checkpoint:
        sim.save(args.checkpoint)
        print(f"saved {args.checkpoint} (full round state, t={sim.t})")
    if logger is not None:
        logger.close()
        print(f"metrics -> {args.metrics_out} "
              f"(python -m repro.obs.report {args.metrics_out})")
    return hist


def _pod_batch(cfg, fl: FLConfig, args):
    C, steps, b, S = fl.cohorts, fl.local_steps, args.batch, args.seq
    data = make_lm_tokens(C * steps * b, S + 1, cfg.vocab_size,
                          n_topics=C, seed=fl.seed)
    tokens = jnp.asarray(
        data["tokens"][:, :S].reshape(C, steps, b, S), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patch_emb"] = jnp.zeros(
            (C, steps, b, cfg.num_patches, cfg.vision_dim),
            jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frame_emb"] = jnp.zeros(
            (C, steps, b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def pod_scale(args, fl: FLConfig):
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    # pod scale's stacked client axis is the cohort count — align the
    # config so comm-plane residual state (aux["comm"], sized by
    # fl.clients_per_round in core.round.init_state) matches the (C, ...)
    # client axis the round step actually carries
    fl = fl.with_(clients_per_round=fl.cohorts)
    strategy = strategies.resolve(fl)
    state = init_state(model, fl, jax.random.PRNGKey(fl.seed), strategy)
    if args.resume:
        state = restore_state(args.resume, state)
        print(f"resumed {args.resume} at round {int(state['t'])}")
    C = fl.cohorts
    environment = env_mod.resolve(
        fl.with_(num_clients=C, clients_per_round=C))
    batch = _pod_batch(cfg, fl, args)
    runner = ChunkRunner(model, fl, strategy, per_round_batch=False,
                         use_scan=not args.no_scan, mesh=engine_mesh(C))

    logger = _logger(args)
    if logger is not None:
        logger.header(fl, payload=payload_bytes(state["params"]),
                      resumed_at=int(state["t"]) or None)

    t_start = int(state["t"])
    # timing through obs.timing: perf_counter spans closed by
    # block_until_ready — JAX dispatch is async, so the seed's bare
    # time.time() around run_chunk measured enqueue, not execution
    dt = 0.0
    with profile_trace(args.profile):
        if args.no_scan:
            # stream per-round progress (a multi-hour pod run must not
            # be silent): one-round chunks through the same runner
            for r in range(args.rounds):
                tr, (state, m) = sync_time(
                    runner.run_chunk, state, batch,
                    environment.batch(t_start + r, 1), scan_ok=False)
                dt += tr
                if logger is not None:
                    logger.rounds(t_start + r, m)
                print(f"round {r}: loss={float(m['loss'][0]):.4f} "
                      f"on_time={int(m['n_on_time'][0])}/{C} "
                      f"({tr:.2f}s)")
        else:
            dt, (state, metrics) = sync_time(
                runner.run_chunk, state, batch,
                environment.batch(t_start, args.rounds))
            if logger is not None:
                logger.rounds(t_start, metrics)
            losses = np.asarray(metrics["loss"])
            on_time = np.asarray(metrics["n_on_time"])
            for r in range(args.rounds):
                print(f"round {r}: loss={losses[r]:.4f} "
                      f"on_time={int(on_time[r])}/{C}")
    engine = "per-round jit loop" if args.no_scan else "one fused scan"
    print(f"{args.rounds} rounds ({engine}): {dt:.2f}s total "
          f"({dt/args.rounds*1e3:.1f} ms/round incl. compile)")
    _print_phases(runner.timer)
    if args.checkpoint:
        save_state(args.checkpoint, state)
        print(f"saved {args.checkpoint} (full round state, "
              f"t={int(state['t'])})")
    if logger is not None:
        logger.phases(runner.timer)
        logger.close()
        print(f"metrics -> {args.metrics_out}")
    return state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-cnn")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced model variant (CPU-sized)")
    ap.add_argument("--algorithm", default="ama_fes",
                    choices=strategies.names())
    ap.add_argument("--env", default="bernoulli", choices=environment_names(),
                    help="environment (channel/device/participation model)")
    ap.add_argument("--scenario", default=None, choices=scenario_names(),
                    help="named environment + config binding; overrides "
                         "--env and the delay knobs (an explicit "
                         "--trace-path still wins)")
    ap.add_argument("--trace-path", default="",
                    help="trace env: .npz schedule to replay "
                         "('' = synthetic mobility trace)")
    ap.add_argument("--no-scan", action="store_true",
                    help="bit-identical per-round jit loop instead of the "
                         "fused chunked scan (both scales)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="paper scale: eval cadence == scan chunk length")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the LEGACY aggregate path's mix through "
                         "the fused Pallas ama_mix (interpret-mode "
                         "off-TPU); only meaningful with "
                         "--server-plane legacy")
    ap.add_argument("--server-plane", default="fused",
                    choices=("fused", "ref", "interpret", "legacy"),
                    help="server-update implementation: one fused pass "
                         "per round (default; pallas on TPU, flat oracle "
                         "off-TPU), the flat jnp oracle, the Pallas "
                         "interpreter (validation only), or the "
                         "pre-fusion per-leaf aggregate chain")
    ap.add_argument("--client-plane", default="masked",
                    choices=("masked", "partitioned"),
                    help="mixed-cohort client execution: one masked "
                         "program for every cohort (default; the "
                         "bit-identity reference) or two programs "
                         "grouped by FES limited-ness — limited cohorts "
                         "never trace the body backward (real Eq. 3 "
                         "computation reduction)")
    ap.add_argument("--population", default="auto",
                    choices=("auto", "dense", "virtual"),
                    help="population realisation: 'auto' keeps the dense "
                         "bit-identical path up to 65536 clients and the "
                         "K-free hashed VirtualPopulation above; "
                         "'dense'/'virtual' force either at any K")
    ap.add_argument("--prefetch-depth", type=int, default=1,
                    help="staged chunks buffered ahead of the device "
                         "(host memory ~ depth x chunk bytes)")
    ap.add_argument("--comm-plane", default="none",
                    choices=("none", "bf16", "q8", "topk"),
                    help="compressed client->server uplink (repro.comm): "
                         "dense f32 (default, bit-identical legacy "
                         "path), bf16 cast (2x), stochastic int8 (~4x) "
                         "or top-k sparsification — all with "
                         "error-feedback residual carried in the round "
                         "state; the bandwidth env and the wire metrics "
                         "consume the real compressed payload size")
    ap.add_argument("--comm-topk-frac", type=float, default=0.01,
                    help="topk plane: surviving fraction of each dtype "
                         "group per round")
    ap.add_argument("--client-reduce", default="auto",
                    choices=("auto", "off", "force"),
                    help="pre-reduce the stacked client axis before the "
                         "server plane ('auto': when the mesh's client "
                         "axis is sharded; collective moves N, not CxN, "
                         "bytes)")
    ap.add_argument("--p-limited", type=float, default=0.25)
    ap.add_argument("--p-delay", type=float, default=0.0)
    ap.add_argument("--max-delay", type=int, default=0)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--clients-per-round", type=int, default=0,
                    help="cohort size m (0 = clients/4, the paper ratio; "
                         "set explicitly for large virtual populations)")
    ap.add_argument("--cohorts", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2, help="pod: per-step batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-train", type=int, default=1500)
    ap.add_argument("--metrics-out", default=None,
                    help="write schema-versioned telemetry JSONL here "
                         "(switches on fl.extended_metrics: per-round "
                         "staleness/participation/mix/norm/wire series; "
                         "summarize with python -m repro.obs.report)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the run in jax.profiler.trace(DIR) with "
                         "named chunk/eval regions (TensorBoard trace)")
    ap.add_argument("--checkpoint", default=None,
                    help="save the full round state {params, t, aux} here")
    ap.add_argument("--resume", default=None,
                    help="restore a full round state and continue "
                         "(bit-identical to an uninterrupted run)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    fl = FLConfig(num_clients=args.clients,
                  clients_per_round=(args.clients_per_round
                                     or max(2, args.clients // 4)),
                  local_epochs=2, local_batch_size=25, lr=args.lr,
                  algorithm=args.algorithm, env=args.env,
                  p_limited=args.p_limited,
                  p_delay=args.p_delay, max_delay=args.max_delay,
                  trace_path=args.trace_path,
                  use_kernel=args.use_kernel,
                  server_plane=args.server_plane,
                  client_plane=args.client_plane,
                  population=args.population,
                  prefetch_depth=args.prefetch_depth,
                  client_reduce=args.client_reduce,
                  comm_plane=args.comm_plane,
                  comm_topk_frac=args.comm_topk_frac,
                  cohorts=args.cohorts, local_steps=args.local_steps,
                  seed=args.seed)
    if args.scenario:
        fl = get_scenario(args.scenario).apply(fl)
        if args.trace_path:       # an explicit recording beats the
            fl = fl.with_(trace_path=args.trace_path)  # scenario default
    if args.metrics_out:
        fl = fl.with_(extended_metrics=True)
    if args.pod:
        pod_scale(args, fl)
    else:
        paper_scale(args, fl)


if __name__ == "__main__":
    main()
