"""Federated training launcher.

Two modes:
  * paper scale (default): K simulated clients on the host device —
    exactly the paper's §V experiment with all heterogeneity knobs.
  * --pod: the pod-scale federated engine (C cohorts over the FL mesh
    view). By default the WHOLE run is one fused ``lax.scan`` program —
    one compile, zero per-round dispatch; ``--no-scan`` falls back to
    the per-round-jit loop (the configuration the round-throughput
    benchmark compares against). On this CPU container it runs the same
    program on the single real device; on a v5e pod the identical code
    spans 256 chips.

``--algorithm`` accepts any name in the server-strategy registry
(repro.core.strategies); ``--env`` any name in the environment registry
(repro.env: bernoulli / gilbert_elliott / bandwidth / trace) and
``--scenario`` any named environment + config binding
(repro.env.scenarios) — adding a strategy/environment/scenario file
extends this launcher with no edits here.

Examples:
  python -m repro.launch.train --arch paper-cnn --rounds 60 --p-limited 0.5
  python -m repro.launch.train --algorithm fedopt --rounds 5
  python -m repro.launch.train --scenario bursty --rounds 40
  python -m repro.launch.train --arch minitron-8b --pod --rounds 3 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import env as env_mod
from repro.checkpoint.io import save
from repro.configs.base import FLConfig, reduced
from repro.configs.registry import (environment_names, get_arch,
                                    get_scenario, scenario_names)
from repro.core import strategies
from repro.core.round import (as_scan_scheds, init_state, make_round_step,
                              make_train_loop)
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification, make_lm_tokens
from repro.models.api import build_model


def paper_scale(args, fl: FLConfig):
    model = build_model(get_arch(args.arch))
    train, test = make_image_classification(
        n_train=args.n_train, n_test=400, seed=fl.seed)
    clients = build_clients(
        train, shard_partition(train["label"], fl.num_clients, seed=fl.seed))
    sim = FederatedSimulation(model, fl, clients, test)
    hist = sim.run(rounds=args.rounds, verbose=True)
    print(f"final: acc={hist.final_accuracy():.4f} "
          f"stability_var={hist.stability_variance():.3f}")
    if args.checkpoint:
        save(args.checkpoint, sim.params)
        print(f"saved {args.checkpoint}")
    return hist


def _pod_batch(cfg, fl: FLConfig, args):
    C, steps, b, S = fl.cohorts, fl.local_steps, args.batch, args.seq
    data = make_lm_tokens(C * steps * b, S + 1, cfg.vocab_size,
                          n_topics=C, seed=fl.seed)
    tokens = jnp.asarray(
        data["tokens"][:, :S].reshape(C, steps, b, S), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patch_emb"] = jnp.zeros(
            (C, steps, b, cfg.num_patches, cfg.vision_dim),
            jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frame_emb"] = jnp.zeros(
            (C, steps, b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def pod_scale(args, fl: FLConfig):
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    strategy = strategies.resolve(fl)
    state = init_state(model, fl, jax.random.PRNGKey(fl.seed), strategy)
    C = fl.cohorts
    environment = env_mod.resolve(
        fl.with_(num_clients=C, clients_per_round=C))
    batch = _pod_batch(cfg, fl, args)
    scheds = as_scan_scheds(environment.batch(0, args.rounds))

    if args.no_scan:
        step = jax.jit(make_round_step(model, fl, strategy))
        for r in range(args.rounds):
            sched = jax.tree.map(lambda x: x[r], scheds)
            t0 = time.time()
            state, metrics = step(state, batch, sched)
            loss = float(metrics["loss"])
            print(f"round {r}: loss={loss:.4f} on_time="
                  f"{int(metrics['n_on_time'])}/{C} ({time.time()-t0:.2f}s)")
    else:
        loop = make_train_loop(model, fl, strategy)
        t0 = time.time()
        state, metrics = loop(state, batch, scheds)
        jax.block_until_ready(metrics)
        dt = time.time() - t0
        losses = np.asarray(metrics["loss"])
        on_time = np.asarray(metrics["n_on_time"])
        for r in range(args.rounds):
            print(f"round {r}: loss={losses[r]:.4f} "
                  f"on_time={int(on_time[r])}/{C}")
        print(f"{args.rounds} rounds in one fused scan: {dt:.2f}s total "
              f"({dt/args.rounds*1e3:.1f} ms/round incl. compile)")
    if args.checkpoint:
        save(args.checkpoint, state["params"])
        print(f"saved {args.checkpoint}")
    return state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-cnn")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced model variant (CPU-sized)")
    ap.add_argument("--algorithm", default="ama_fes",
                    choices=strategies.names())
    ap.add_argument("--env", default="bernoulli", choices=environment_names(),
                    help="environment (channel/device/participation model)")
    ap.add_argument("--scenario", default=None, choices=scenario_names(),
                    help="named environment + config binding; overrides "
                         "--env and the delay knobs (an explicit "
                         "--trace-path still wins)")
    ap.add_argument("--trace-path", default="",
                    help="trace env: .npz schedule to replay "
                         "('' = synthetic mobility trace)")
    ap.add_argument("--no-scan", action="store_true",
                    help="pod: per-round jit loop instead of the fused scan")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the server mix through the fused Pallas "
                         "kernel (interpret-mode off-TPU)")
    ap.add_argument("--p-limited", type=float, default=0.25)
    ap.add_argument("--p-delay", type=float, default=0.0)
    ap.add_argument("--max-delay", type=int, default=0)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--cohorts", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2, help="pod: per-step batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-train", type=int, default=1500)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    fl = FLConfig(num_clients=args.clients,
                  clients_per_round=max(2, args.clients // 4),
                  local_epochs=2, local_batch_size=25, lr=args.lr,
                  algorithm=args.algorithm, env=args.env,
                  p_limited=args.p_limited,
                  p_delay=args.p_delay, max_delay=args.max_delay,
                  trace_path=args.trace_path,
                  use_kernel=args.use_kernel,
                  cohorts=args.cohorts, local_steps=args.local_steps,
                  seed=args.seed)
    if args.scenario:
        fl = get_scenario(args.scenario).apply(fl)
        if args.trace_path:       # an explicit recording beats the
            fl = fl.with_(trace_path=args.trace_path)  # scenario default
    if args.pod:
        pod_scale(args, fl)
    else:
        paper_scale(args, fl)


if __name__ == "__main__":
    main()
