"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production mesh and report memory/FLOPs/collectives (no real allocation).

MUST set the placeholder device count before any other import touches jax.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, FLConfig, ModelConfig, ShapeConfig
from repro.configs.registry import (ASSIGNED, LONG_CONTEXT_OK, get_arch,
                                    get_shape, pairs, serving_config)
from repro.core.round import make_train_step_for_lowering
from repro.launch.mesh import fl_view, make_production_mesh, serve_view
from repro.models.api import build_model, input_specs
from repro.sharding import specs as sh
from repro.utils.hlo import collective_stats

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../experiments/artifacts/dryrun")


# ------------------------------------------------------------ builders -----

# Per-arch FL round geometry: big archs need fewer parallel cohorts (each
# cohort is a full model replica) and deeper microbatching to bound the
# activation-checkpoint stack. C * (params + grads + f32 staging) has to
# fit the pod; see EXPERIMENTS.md §Dry-run for the fit analysis.
ARCH_FL = {
    "minitron-8b": dict(cohorts=4, local_steps=8),   # §Perf H3: peak 13.4->7.2 GiB
    "llama3-405b": dict(cohorts=2, local_steps=16),
    "mistral-large-123b": dict(cohorts=2, local_steps=8),
    "qwen1.5-110b": dict(cohorts=2, local_steps=8),
    "mixtral-8x22b": dict(cohorts=2, local_steps=8),
    "phi3.5-moe-42b-a6.6b": dict(cohorts=4, local_steps=8),
}

# per-arch TP width on the training mesh (§Perf H2): rwkv6's 40 heads /
# zamba2's head layout shard evenly over 8, making the head reshape a
# LOCAL op instead of an all-gather of every projection output.
ARCH_MODEL_WIDTH = {
    "rwkv6-3b": 8,
    "zamba2-1.2b": 8,
}


def fl_for(arch: str) -> "FLConfig":
    return default_fl(**ARCH_FL.get(arch, {}))


def default_fl(cohorts: int = 4, local_steps: int = 4) -> FLConfig:
    """Dry-run FL config: the shape's global batch is one federated round's
    traffic, split into ``local_steps`` sequential microbatch SGD steps per
    cohort (paper: e=10 local epochs -> several local steps per round).
    Microbatching also bounds the activation-checkpoint stack: per-device
    live tokens = global_batch*seq/(cohorts*local_steps*dsub)."""
    return FLConfig(cohorts=cohorts, local_steps=local_steps,
                    algorithm="ama_fes", max_delay=0, p_limited=0.25)


def ep_factor(cfg: ModelConfig, n_model: int = 16) -> int:
    """Factorized (expert, etp) mesh — EVALUATED AND REFUTED for this
    workload (§Perf H1-it5): splitting the model axis regressed compute
    2.8x vs constraining the capacity dim onto the whole model axis,
    because the within-expert-TP layout conflicts with the dispatch
    layout on the narrow etp sub-axis. Kept (return 0 disables it) so the
    experiment is reproducible; the production scheme is H1-it4."""
    return 0


def train_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh, fl: FLConfig):
    """Lower the federated round (train_step) on the FL mesh view."""
    model = build_model(cfg)
    fmesh = fl_view(mesh, fl.cohorts, expert_parallel=ep_factor(cfg),
                    model_width=ARCH_MODEL_WIDTH.get(cfg.name, 0))
    C = fmesh.shape["client"]
    steps = fl.local_steps
    b = shape.global_batch // (C * steps)
    if b == 0:
        raise ValueError(f"batch {shape.global_batch} too small for "
                         f"C={C} x steps={steps}")

    base = input_specs(cfg, shape)["batch"]
    batch = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((C, steps, b) + s.shape[1:], s.dtype),
        base)
    sched = {
        "limited": jax.ShapeDtypeStruct((C,), jnp.bool_),
        "delayed": jax.ShapeDtypeStruct((C,), jnp.bool_),
        "delays": jax.ShapeDtypeStruct((C,), jnp.int32),
        "data_sizes": jax.ShapeDtypeStruct((C,), jnp.float32),
    }
    params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    t_like = jax.ShapeDtypeStruct((), jnp.int32)

    p_sh = sh.params_shardings(params_like, cfg, fmesh, train=True)
    in_shardings = (
        p_sh,
        sh.replicated(t_like, fmesh),
        sh.batch_shardings(batch, fmesh, train=True),
        sh.sched_shardings(sched, fmesh),
    )
    step = make_train_step_for_lowering(model, fl)
    jitted = jax.jit(step, in_shardings=in_shardings,
                     out_shardings=(p_sh, None))
    with fmesh:
        lowered = jitted.lower(params_like, t_like, batch, sched)
    return lowered


def prefill_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh):
    model = build_model(cfg)
    smesh = serve_view(mesh, expert_parallel=ep_factor(cfg))
    batch = input_specs(cfg, shape)["batch"]
    params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = sh.params_shardings(params_like, cfg, smesh, train=False)
    b_sh = sh.batch_shardings(batch, smesh, train=False)

    jitted = jax.jit(model.prefill_logits, in_shardings=(p_sh, b_sh),
                     out_shardings=None)
    with smesh:
        lowered = jitted.lower(params_like, batch)
    return lowered


def decode_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh):
    model = build_model(cfg)
    smesh = serve_view(mesh, expert_parallel=ep_factor(cfg))
    ins = input_specs(cfg, shape)
    params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = sh.params_shardings(params_like, cfg, smesh, train=False)
    c_sh = sh.cache_shardings(ins["cache"], cfg, smesh)
    tok_sh = sh.batch_shardings(ins["token"], smesh, train=False)
    pos_sh = sh.batch_shardings(ins["position"], smesh, train=False)

    jitted = jax.jit(model.decode_step,
                     in_shardings=(p_sh, tok_sh, pos_sh, c_sh),
                     out_shardings=(None, c_sh))
    with smesh:
        lowered = jitted.lower(params_like, ins["token"], ins["position"],
                               ins["cache"])
    return lowered


def build_lowering(arch: str, shape_name: str, mesh, fl: FLConfig = None,
                   cfg_overrides: dict = None):
    """Deploy lowering: scanned loops (the program you would actually run);
    memory_analysis is truthful. Roofline FLOPs come from the costing
    lowerings in benchmarks/costing.py (unrolled + depth-calibrated),
    because HloCostAnalysis counts scan bodies once."""
    shape = get_shape(shape_name)
    cfg = get_arch(arch) if shape.kind == "train" else serving_config(arch)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    if shape.kind == "train":
        return train_lowering(cfg, shape, mesh, fl or default_fl())
    if shape.kind == "prefill":
        return prefill_lowering(cfg, shape, mesh)
    return decode_lowering(cfg, shape, mesh)


# ------------------------------------------------------------ analysis -----

def analyse(lowered, compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    out = {
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll.total_bytes,
        "collectives": {k: {"n": coll.counts[k], "bytes": coll.bytes_[k]}
                        for k in coll.counts},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
    }
    return out


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             fl: FLConfig = None, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = build_lowering(arch, shape_name, mesh, fl or fl_for(arch))
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    rec = analyse(lowered, compiled)
    rec.update(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1))
    if verbose:
        mem = rec["memory"]
        arg = (mem["argument_bytes"] or 0) / 2**30
        tmp = (mem["temp_bytes"] or 0) / 2**30
        print(f"[{arch} x {shape_name} @ {rec['mesh']}] "
              f"flops={rec['hlo_flops']:.3e} bytes={rec['hlo_bytes']:.3e} "
              f"coll={rec['collective_bytes']:.3e}B "
              f"mem(arg={arg:.2f}GiB temp={tmp:.2f}GiB) "
              f"lower={rec['lower_s']}s compile={rec['compile_s']}s")
    return rec


def save_record(rec: dict, tag: str = ""):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh'].replace('x','-')}{tag}.json"
    with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every assigned (arch x shape) pair")
    ap.add_argument("--cohorts", type=int, default=4)
    args = ap.parse_args()

    fl = default_fl(args.cohorts) if args.cohorts != 4 else None
    todo = []
    if args.all:
        todo = pairs()
    else:
        archs = [args.arch] if args.arch else ASSIGNED
        shapes = [args.shape] if args.shape else list(SHAPES)
        for a in archs:
            for s in shapes:
                skip = s == "long_500k" and not LONG_CONTEXT_OK[a]
                todo.append((a, s, skip))

    ok = fail = skipped = 0
    for arch, shape_name, skip in todo:
        if skip:
            print(f"[{arch} x {shape_name}] SKIP (full attention at 524k; "
                  f"see DESIGN.md)")
            skipped += 1
            continue
        try:
            rec = run_pair(arch, shape_name, multi_pod=args.multi_pod, fl=fl)
            save_record(rec)
            ok += 1
        except Exception as e:  # a failure here is a bug in the system
            print(f"[{arch} x {shape_name}] FAILED: {type(e).__name__}: "
                  f"{str(e)[:300]}")
            fail += 1
    print(f"\ndry-run done: {ok} ok, {fail} failed, {skipped} skipped")
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
