"""Client data pipeline: per-round local batch sampling."""
from __future__ import annotations

import numpy as np


class ClientDataset:
    """One client's local shard with epoch-style batch sampling."""

    def __init__(self, data: dict, indices: np.ndarray):
        self.data = data
        self.indices = np.asarray(indices)

    def __len__(self):
        return len(self.indices)

    def sample_steps(self, rng: np.random.RandomState, steps: int,
                     batch_size: int):
        """(steps, batch, ...) arrays, sampling with reshuffled epochs."""
        n = len(self.indices)
        need = steps * batch_size
        reps = int(np.ceil(need / max(n, 1)))
        idx = np.concatenate([rng.permutation(self.indices) for _ in range(reps)])
        idx = idx[:need].reshape(steps, batch_size)
        return {k: v[idx] for k, v in self.data.items()}


def build_clients(data: dict, partition: list[np.ndarray]) -> list[ClientDataset]:
    return [ClientDataset(data, idx) for idx in partition]


def batch_iterator(data: dict, batch_size: int, seed: int = 0):
    n = len(next(iter(data.values())))
    rng = np.random.RandomState(seed)
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sl = order[i:i + batch_size]
            yield {k: v[sl] for k, v in data.items()}
