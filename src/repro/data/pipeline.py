"""Client data pipeline: per-round sampling + the vectorized chunk stager.

The execution engine consumes data in CHUNKS of rounds: one fancy-gather
produces the whole ``(n_rounds, C, steps, b, ...)`` batch tensor a
``per_round_batch`` scan needs, replacing the per-client/per-round
Python staging loops. Index computation is host-side numpy (cheap); the
gather touches the actual sample arrays exactly once per chunk.

THE STAGING CONTRACT (mirrors the ``Environment`` schedule contract):
round t's batch indices are a pure function of (seed, t, selected[t]) —
``stage_chunk(t0, n)`` row i is bit-identical to staging round t0+i on
its own. Chunked execution, the per-round fallback and a resumed run
therefore all see the same sample stream.

``ChunkPrefetcher`` overlaps host staging with device execution: a
single worker thread stages chunk k+1 while chunk k runs on device
(depth-1 double buffering, so stateful environments are never entered
concurrently).

``partition_plan`` is the staging half of the PARTITIONED client plane
(``fl.client_plane``): it groups each round's cohorts by FES
limited-ness into static-width dispatch/scatter index arrays that ride
the schedule dict into the compiled round.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


def sample_shard_steps(indices: np.ndarray, rng: np.random.RandomState,
                       steps: int, batch_size: int) -> np.ndarray:
    """(steps, batch) global indices from one shard, reshuffled-epoch
    order — THE sampling algorithm, shared by the dense ``ClientDataset``
    list and the K-free ``VirtualClientShards`` so both draw
    bit-identical streams from identical shard index arrays."""
    n = len(indices)
    need = steps * batch_size
    reps = int(np.ceil(need / max(n, 1)))
    idx = np.concatenate([rng.permutation(indices) for _ in range(reps)])
    return idx[:need].reshape(steps, batch_size)


class ClientDataset:
    """One client's local shard with epoch-style batch sampling."""

    def __init__(self, data: dict, indices: np.ndarray):
        self.data = data
        self.indices = np.asarray(indices)

    def __len__(self):
        return len(self.indices)

    def sample_step_indices(self, rng: np.random.RandomState, steps: int,
                            batch_size: int) -> np.ndarray:
        """(steps, batch) GLOBAL sample indices, reshuffled-epoch order."""
        return sample_shard_steps(self.indices, rng, steps, batch_size)

    def sample_steps(self, rng: np.random.RandomState, steps: int,
                     batch_size: int):
        """(steps, batch, ...) arrays, sampling with reshuffled epochs."""
        idx = self.sample_step_indices(rng, steps, batch_size)
        return {k: v[idx] for k, v in self.data.items()}


def build_clients(data: dict, partition: list[np.ndarray]) -> list[ClientDataset]:
    return [ClientDataset(data, idx) for idx in partition]


class VirtualClientShards:
    """K clients over ONE base store with no per-client objects — the
    staging half of a virtual population (``repro.env.virtual``).

    A single base permutation (drawn once from the staging seed, off the
    round axis) defines every shard arithmetically: client i owns
    ``order[(i * shard_size + j) % n]`` for j < shard_size. Client i's
    shard is therefore a pure function of (i, seed) — nothing is
    materialised per client, so K = 10^6 costs the same as K = 20. Once
    K * shard_size exceeds the base store the shards overlap by wrapping
    around the permutation (distinct clients still hold distinct,
    deterministic index sets — the standard trick for simulating
    populations far larger than the benchmark corpus).

    Duck-type contract with ``list[ClientDataset]`` where the engine and
    stager need it: ``len``, ``.data``, and per-client index sampling —
    dispatch is on the ``shard_indices`` attribute.
    """

    def __init__(self, data: dict, num_clients: int,
                 shard_size: int | None = None, seed: int = 0):
        self.data = data
        self.num_clients = int(num_clients)
        self.n = len(next(iter(data.values())))
        if shard_size is None:
            shard_size = max(1, self.n // self.num_clients)
        self.shard_size = int(shard_size)
        assert 0 < self.shard_size <= self.n, (self.shard_size, self.n)
        self.order = np.random.RandomState(
            (seed + 0xA5F152) % 2**32).permutation(self.n)

    def __len__(self):
        return self.num_clients

    @property
    def min_size(self) -> int:
        return self.shard_size

    def shard_indices(self, i: int) -> np.ndarray:
        start = (int(i) * self.shard_size) % self.n
        return self.order[(start + np.arange(self.shard_size)) % self.n]

    def sample_step_indices(self, i: int, rng: np.random.RandomState,
                            steps: int, batch_size: int) -> np.ndarray:
        return sample_shard_steps(self.shard_indices(i), rng, steps,
                                  batch_size)

    def client_sizes(self, selected: np.ndarray) -> np.ndarray:
        """|D_i| aggregation weights — the ``data_sizes`` callable the
        environment layer consumes (``env.resolve(fl, data_sizes=...)``)."""
        return np.full(np.shape(selected), self.shard_size, np.float32)


# --------------------------------------------------------------------------
# chunked staging (the engine's data plane)
# --------------------------------------------------------------------------

def stage_rng(seed: int, t: int) -> np.random.RandomState:
    """Round t's batch-sampling stream — independent per round, keyed on
    the absolute round index (cf. ``env.base.round_rng``), so staging is
    pure in t and survives chunking/resume unchanged."""
    return np.random.RandomState(
        (seed * 1_000_003 + t + 0x51ED270) % 2**32)


def stage_round_indices(clients, selected: np.ndarray,
                        seed: int, t: int, steps: int,
                        batch_size: int) -> np.ndarray:
    """(C, steps, batch) global indices for round t's selected clients.

    ``clients`` is either the dense ``list[ClientDataset]`` or a
    ``VirtualClientShards``; both consume the shared per-round stream in
    selected order, so a dense list built from ``shards.shard_indices``
    stages bit-identical batches. Cost is O(C x steps x batch) either
    way — never O(K)."""
    rng = stage_rng(seed, t)
    if hasattr(clients, "shard_indices"):
        return np.stack([clients.sample_step_indices(int(i), rng, steps,
                                                     batch_size)
                         for i in selected])
    return np.stack([clients[int(i)].sample_step_indices(rng, steps,
                                                         batch_size)
                     for i in selected])


def stage_chunk(data: dict, clients,
                selected: np.ndarray, seed: int, t0: int, steps: int,
                batch_size: int) -> dict:
    """Stage a whole chunk of rounds with ONE gather per data field.

    selected: (n_rounds, C) client indices (``Environment.batch`` rows).
    Returns {field: (n_rounds, C, steps, batch, ...)} numpy arrays —
    exactly the ``per_round_batch`` layout ``make_train_loop`` scans
    over. Row i is bit-identical to staging round ``t0 + i`` alone.
    """
    selected = np.asarray(selected)
    idx = np.stack([stage_round_indices(clients, selected[i], seed, t0 + i,
                                        steps, batch_size)
                    for i in range(selected.shape[0])])
    return {k: v[idx] for k, v in data.items()}


def partition_plan(limited: np.ndarray) -> dict:
    """Host-side dispatch plan for the PARTITIONED client plane.

    ``limited``: (n_rounds, C) bool — the chunk's stacked FES flags from
    ``Environment.batch``. Groups each round's cohorts by limited-ness
    into two programs with STATIC widths across the chunk (the fused
    round scan needs one shape for every round):

      * the limited (classifier-only / truncated) program takes
        ``L = min`` limited count over the chunk's rounds;
      * the full (masked) program takes the remaining ``U = C - L``
        slots — unlimited cohorts plus any round's OVERFLOW limited
        cohorts, which stay correct there (masked, just unreduced).

    A 1-round chunk — the per-round fallback, ``run_round``, the pod
    ``--no-scan`` loop — therefore gets the exact per-round split with
    no overflow. Returned arrays (consumed by
    ``core.client.make_partitioned_local_train`` via the schedule dict):

      part_full_idx (n, U) — cohort slot feeding full-program row u
      part_lim_idx  (n, L) — cohort slot feeding limited-program row l
      part_src_row  (n, C) — slot c's row in its program's stacked output
      part_from_lim (n, C) — True where that program is the limited one
    """
    limited = np.asarray(limited, bool)
    if limited.ndim != 2:
        raise ValueError(f"limited must be (n_rounds, C), got "
                         f"{limited.shape}")
    n, C = limited.shape
    L = int(limited.sum(axis=1).min())
    U = C - L
    full_idx = np.zeros((n, U), np.int32)
    lim_idx = np.zeros((n, L), np.int32)
    src_row = np.zeros((n, C), np.int32)
    from_lim = np.zeros((n, C), bool)
    for i in range(n):
        lim = np.flatnonzero(limited[i])[:L].astype(np.int32)
        full = np.setdiff1d(np.arange(C, dtype=np.int32), lim)
        lim_idx[i], full_idx[i] = lim, full
        from_lim[i, lim] = True
        src_row[i, lim] = np.arange(L, dtype=np.int32)
        src_row[i, full] = np.arange(U, dtype=np.int32)
    return {"part_full_idx": full_idx, "part_lim_idx": lim_idx,
            "part_src_row": src_row, "part_from_lim": from_lim}


class ChunkPrefetcher:
    """Stage chunk k+1 on a host thread while chunk k runs on device.

    ``fn(item)`` is called on a SINGLE worker thread in item order (so
    stateful environments and shared RNG-free staging are safe); at most
    ``depth`` staged chunks are buffered ahead of the consumer.
    """

    def __init__(self, fn, items, depth: int = 1):
        self._q = queue.Queue(maxsize=max(depth, 1))
        self._n = len(items)
        self._stop = threading.Event()

        def put(item) -> bool:
            while not self._stop.is_set():      # closed consumers release
                try:                            # the worker (no leaked
                    self._q.put(item, timeout=0.1)   # thread/chunk buffer)
                    return True
                # fedlint: disable=FED106 — bounded 0.1s poll; _stop is the exit
                except queue.Full:
                    continue
            return False

        def work():
            for it in items:
                if self._stop.is_set():
                    return
                try:
                    staged = (fn(it), None)
                except Exception as e:          # surface on the consumer side
                    put((None, e))
                    return
                if not put(staged):
                    return

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _drain(self) -> None:
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                return

    def close(self) -> None:
        """Stop staging and drop buffered chunks (abandoned iteration)."""
        self._stop.set()
        self._drain()
        # an in-flight put can land after the first drain; once the
        # worker observes the stop flag and exits, drain what it left
        self._thread.join(timeout=1.0)
        self._drain()

    def __iter__(self):
        try:
            for _ in range(self._n):
                out, err = self._q.get()
                if err is not None:
                    raise err
                yield out
        finally:
            self.close()


def batch_iterator(data: dict, batch_size: int, seed: int = 0):
    n = len(next(iter(data.values())))
    rng = np.random.RandomState(seed)
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sl = order[i:i + batch_size]
            yield {k: v[sl] for k, v in data.items()}
