"""Non-iid client partitioners.

``shard_partition`` is the paper's setting ([1]'s pathological non-iid):
sort by label, cut into 2*K shards, give each client 2 shards -> each
client holds samples from at most two classes.

``dirichlet_partition`` is the standard milder alternative (ablations).
"""
from __future__ import annotations

import numpy as np


def shard_partition(labels: np.ndarray, num_clients: int,
                    shards_per_client: int = 2, seed: int = 0):
    """Each client receives ``shards_per_client`` single-class shards, so it
    sees at most that many classes — the paper's strict property. (Naive
    sort-and-cut lets shards straddle class boundaries.) Exact cover: every
    sample is assigned to exactly one client."""
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    # class slot list: 2*K slots cycling through classes, shuffled
    slots = np.array([i % n_classes
                      for i in range(num_clients * shards_per_client)])
    rng.shuffle(slots)
    idx_by_class = []
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        idx_by_class.append(idx)
    # first pass: every class that has samples needs at least one slot
    # (possible when n_classes > shards_per_client * num_clients)
    extra_slots = []   # (client, class) — only when slots < classes
    for c in range(n_classes):
        if len(idx_by_class[c]) and not np.any(slots == c):
            # steal a slot from a class with more than one holder
            donors = [s for s in range(len(slots))
                      if np.sum(slots == slots[s]) > 1]
            if donors:
                slots[donors[rng.randint(len(donors))]] = c
            else:
                # fewer slots than classes: exact cover wins over the
                # <=shards_per_client-classes property (degenerate regime;
                # the paper's K=50, 2 shards, 10 classes never hits this)
                extra_slots.append((rng.randint(num_clients), c))
    # second pass: split each class's samples among its holders
    class_chunks = {}
    for c in range(n_classes):
        holders = np.where(slots == c)[0]
        if len(holders) == 0:
            class_chunks[c] = {}
            continue
        class_chunks[c] = dict(
            zip(holders.tolist(), np.array_split(idx_by_class[c],
                                                 len(holders))))
    out = []
    for client in range(num_clients):
        mine = []
        for s in range(shards_per_client):
            slot = client * shards_per_client + s
            c = slots[slot]
            if slot in class_chunks[c]:
                mine.append(class_chunks[c][slot])
        for cl, c in extra_slots:
            if cl == client:
                mine.append(idx_by_class[c])
        idx = (np.concatenate(mine) if mine
               else np.array([], dtype=np.int64))
        rng.shuffle(idx)
        out.append(idx.astype(np.int64))
    return out


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, seed: int = 0):
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    client_idx = [[] for _ in range(num_clients)]
    for idx in idx_by_class:
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for c, part in enumerate(np.split(idx, cuts)):
            client_idx[c].append(part)
    out = []
    for c in range(num_clients):
        idx = np.concatenate(client_idx[c]) if client_idx[c] else np.array([], int)
        rng.shuffle(idx)
        out.append(idx.astype(np.int64))
    return out


def iid_partition(n: int, num_clients: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    return [a.astype(np.int64) for a in np.array_split(idx, num_clients)]
