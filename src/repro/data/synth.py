"""Synthetic datasets (the container is offline — no MNIST download).

``make_image_classification`` generates an MNIST-shaped dataset
(28x28x1, 10 classes) whose classes are genuinely learnable but not
linearly trivial: each class is a random frequency-structured template +
per-sample random affine-ish jitter + noise. The FL-relevant properties of
the paper's setup — class structure, non-iid shardability, train/test
split — are preserved; EXPERIMENTS.md records the substitution.

``make_lm_tokens`` generates token streams from a class-conditional
bigram process so that language-model archs also see non-iid-shardable
synthetic data (each "client topic" = one bigram table).
"""
from __future__ import annotations

import numpy as np


def make_image_classification(n_train: int = 6000, n_test: int = 1000,
                              n_classes: int = 10, seed: int = 0):
    rng = np.random.RandomState(seed)
    # class templates: smooth random fields (low-freq fourier mix)
    xs = np.linspace(0, 1, 28)
    xx, yy = np.meshgrid(xs, xs)
    templates = []
    for c in range(n_classes):
        t = np.zeros((28, 28))
        for _ in range(4):
            fx, fy = rng.randint(1, 5, size=2)
            ph = rng.rand(2) * 2 * np.pi
            t += rng.randn() * np.sin(2 * np.pi * fx * xx + ph[0]) \
                * np.sin(2 * np.pi * fy * yy + ph[1])
        templates.append(t / np.abs(t).max())
    templates = np.stack(templates)                       # (C, 28, 28)

    def gen(n):
        labels = rng.randint(0, n_classes, size=n)
        base = templates[labels]
        # per-sample jitter: random shift + scale + noise
        shift = rng.randint(-2, 3, size=(n, 2))
        imgs = np.empty((n, 28, 28), np.float32)
        for i in range(n):
            imgs[i] = np.roll(np.roll(base[i], shift[i, 0], 0), shift[i, 1], 1)
        imgs = imgs * (0.8 + 0.4 * rng.rand(n, 1, 1))
        imgs += 0.35 * rng.randn(n, 28, 28)
        return {"image": imgs[..., None].astype(np.float32),
                "label": labels.astype(np.int32)}

    return gen(n_train), gen(n_test)


def make_lm_tokens(n_seqs: int, seq_len: int, vocab: int, n_topics: int = 10,
                   seed: int = 0):
    """Class-conditional first-order Markov token streams."""
    rng = np.random.RandomState(seed)
    V = min(vocab, 1024)          # active vocab slice (rest unused)
    trans = rng.dirichlet(np.full(V, 0.05), size=(n_topics, V))   # (T, V, V)
    topics = rng.randint(0, n_topics, size=n_seqs)
    out = np.empty((n_seqs, seq_len), np.int32)
    for i in range(n_seqs):
        T = trans[topics[i]]
        tok = rng.randint(0, V)
        for j in range(seq_len):
            out[i, j] = tok
            tok = rng.choice(V, p=T[tok])
    return {"tokens": out, "label": topics.astype(np.int32)}
