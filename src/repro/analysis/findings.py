"""The one finding schema every repo gate emits.

``Finding`` is fedlint's unit of output, and ``findings_json`` is the
uniform machine-readable artifact format shared by all three CI gates —
``python -m repro.analysis --json``, ``scripts/check_metrics.py --json``
and ``scripts/check_bench.py --json`` — so a workflow consumer parses
ONE schema regardless of which gate produced the file:

    {"tool": "...", "schema_version": 1,
     "findings": [{rule, path, line, col, message, severity,
                   suppressed, justification}, ...],
     "summary": {"total": n, "suppressed": m, "unsuppressed": k}}

Exit-code convention everywhere: 0 iff ``summary.unsuppressed == 0``.
"""
from __future__ import annotations

import dataclasses
import json

SCHEMA_VERSION = 1


@dataclasses.dataclass
class Finding:
    """One rule violation at one location.

    ``path`` is repo-relative for AST findings; jaxpr/lowering findings
    use a ``<trace:config-label>`` pseudo-path (they locate a traced
    program, not a source line) and ``line`` 0."""

    rule: str
    path: str
    line: int
    message: str
    col: int = 0
    severity: str = "error"
    suppressed: bool = False
    justification: str | None = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tag = (f" [suppressed: {self.justification}]" if self.suppressed
               else "")
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}{tag}")


def summarize(findings: list[Finding]) -> dict:
    sup = sum(1 for f in findings if f.suppressed)
    return {"total": len(findings), "suppressed": sup,
            "unsuppressed": len(findings) - sup}


def findings_json(tool: str, findings: list[Finding],
                  extra: dict | None = None) -> dict:
    """The uniform gate-artifact record (see module docstring)."""
    rec = {"tool": tool, "schema_version": SCHEMA_VERSION,
           "findings": [f.to_dict() for f in findings],
           "summary": summarize(findings)}
    if extra:
        rec.update(extra)
    return rec


def write_json(path: str, tool: str, findings: list[Finding],
               extra: dict | None = None) -> None:
    with open(path, "w") as fh:
        json.dump(findings_json(tool, findings, extra), fh, indent=2)
        fh.write("\n")
