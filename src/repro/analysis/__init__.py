"""fedlint — the repo's static invariant analyzer (two layers).

Layer 1 (``ast_rules``) reads host-side Python over ``src/``,
``benchmarks/``, ``scripts/``; layer 2 (``jaxpr_rules``) traces the
engine's real programs from the strategy registry and checks the
lowering/jaxpr. Run it as::

    PYTHONPATH=src python -m repro.analysis [--json] [--out FILE]
                                            [--select RULE,...] [paths]

Exit 0 iff there are zero unsuppressed findings. Suppress a finding on
its line with ``# fedlint: disable=RULE — <justification>`` (see
``suppress``). The rule catalogue lives in ``RULES``; each entry names
the invariant and the incident that motivated it (README "Static
analysis & invariants").
"""
from __future__ import annotations

import dataclasses

from repro.analysis.findings import (Finding, findings_json, summarize,
                                     write_json)


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    name: str
    layer: str          # "ast" | "jaxpr"
    doc: str


RULES: dict[str, Rule] = {r.id: r for r in [
    Rule("FED100", "suppression-without-justification", "ast",
         "a '# fedlint: disable=...' comment must say WHY it is safe"),
    Rule("FED101", "use-after-donate", "ast",
         "a buffer passed to a donate_argnums jit is read again before "
         "reassignment (donated storage is invalid after the call)"),
    Rule("FED102", "host-nondeterminism", "ast",
         "np.random/time/random inside traced code — baked in at trace "
         "time, breaks scan==loop==resume (the PR 7 timing fictions)"),
    Rule("FED103", "scan-side-effect", "ast",
         "Python side effect inside a lax.scan/loop body — runs once at "
         "trace time, not per round"),
    Rule("FED104", "kernel-side-effect", "ast",
         "Python side effect inside a pallas_call kernel body"),
    Rule("FED105", "bare-except", "ast",
         "'except:' catches KeyboardInterrupt/SystemExit"),
    Rule("FED106", "swallowed-exception", "ast",
         "except body that is only 'pass' in checkpoint/prefetcher "
         "paths — failures there must surface"),
    Rule("FED201", "donation-aliasing", "jaxpr",
         "the donated round carry must actually alias in the lowering "
         "(tf.aliasing_output per params leaf)"),
    Rule("FED202", "effectful-scan-primitive", "jaxpr",
         "no callback/infeed/outfeed primitives or JAX effects inside "
         "the fused round scan body"),
    Rule("FED203", "carry-stability", "jaxpr",
         "round_step must map the state pytree onto its own structure/"
         "shapes/dtypes (what scan and resume require)"),
    Rule("FED204", "kernel-oracle-parity", "jaxpr",
         "every Pallas kernel entry needs a ref.*_math/_ref oracle with "
         "an identical positional signature (the PR 4/9 contract)"),
]}

__all__ = ["RULES", "Rule", "Finding", "findings_json", "summarize",
           "write_json", "run_paths", "run_traces"]


def run_paths(paths, select=None) -> list[Finding]:
    """Layer 1 over ``paths`` (files or directories), suppressions
    applied, findings sorted by location."""
    import os

    from repro.analysis.ast_rules import run_file
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = [d for d in dirs
                       if d != "__pycache__" and not d.startswith(".")]
            files.extend(os.path.join(root, n) for n in sorted(names)
                         if n.endswith(".py"))
    findings = []
    for f in sorted(set(files)):
        with open(f, encoding="utf-8") as fh:
            src = fh.read()
        findings.extend(run_file(os.path.relpath(f), src, select))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def run_traces(select=None) -> list[Finding]:
    """Layer 2 over the real registries (see ``jaxpr_rules``)."""
    from repro.analysis import jaxpr_rules
    return jaxpr_rules.run(select)
