"""CLI front of fedlint: ``python -m repro.analysis``.

With no paths it analyzes the repo's default surface (``src``,
``benchmarks``, ``scripts`` under the cwd) AND runs the layer-2 trace
rules; with explicit paths it runs the AST layer on just those (add
``--select FED201,...`` to force trace rules too). ``--json`` prints
the uniform gate-artifact schema (``repro.analysis.findings``) to
stdout; ``--out FILE`` writes it alongside the human report — CI uses
``--out`` so the findings JSON is uploaded even on a green run.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import RULES, findings_json, run_paths, run_traces
from repro.analysis.findings import summarize, write_json

DEFAULT_PATHS = ("src", "benchmarks", "scripts")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="fedlint: jaxpr- and AST-level invariant analyzer")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs for the AST layer (default: "
                         "src benchmarks scripts + trace rules)")
    ap.add_argument("--json", action="store_true",
                    help="print the findings JSON to stdout")
    ap.add_argument("--out", default=None,
                    help="also write the findings JSON to this path")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run (default all)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in RULES.values():
            print(f"{r.id}  [{r.layer:5s}] {r.name}: {r.doc}")
        return 0

    select = None
    if args.select:
        select = {s.strip() for s in args.select.split(",") if s.strip()}
        unknown = select - set(RULES)
        if unknown:
            print(f"unknown rules: {sorted(unknown)}; known: "
                  f"{sorted(RULES)}", file=sys.stderr)
            return 2

    explicit = bool(args.paths)
    paths = args.paths or [p for p in DEFAULT_PATHS if os.path.isdir(p)]
    findings = run_paths(paths, select)
    run_layer2 = (not explicit) or (
        select is not None and any(RULES[r].layer == "jaxpr"
                                   for r in select))
    if run_layer2:
        findings.extend(run_traces(select))

    summ = summarize(findings)
    if args.out:
        write_json(args.out, "fedlint", findings)
    if args.json:
        import json
        json.dump(findings_json("fedlint", findings), sys.stdout, indent=2)
        print()
    else:
        for f in findings:
            print(f.render())
        print(f"fedlint: {summ['total']} findings "
              f"({summ['suppressed']} suppressed, "
              f"{summ['unsuppressed']} unsuppressed) over "
              f"{len(paths)} path(s)"
              + ("" if run_layer2 else " [AST layer only]"))
    return 1 if summ["unsuppressed"] else 0


if __name__ == "__main__":
    sys.exit(main())
