"""Layer 2: jaxpr- and lowering-level invariants, driven from the
registries.

Where layer 1 reads source, this layer traces the REAL programs: for
every registered ``ServerStrategy`` x a small config matrix it builds
the engine's actual ``make_train_loop`` (the same callable ChunkRunner
jits) against abstract inputs and asserts

  FED201 donation-aliasing        the donated round carry actually
                                  aliases in the lowering (every params
                                  leaf carries ``tf.aliasing_output``) —
                                  a dropped donation silently doubles
                                  the HBM watermark at LLM scale
  FED202 effectful-scan-primitive no callback/infeed/outfeed primitives
                                  and no JAX effects inside the round
                                  scan body (a debug print in the scan
                                  is a per-chunk host sync)
  FED203 carry-stability          one round step maps the state pytree
                                  onto exactly its own structure/shapes/
                                  dtypes (what scan and bit-identical
                                  resume both require)
  FED204 kernel-oracle-parity     every public Pallas kernel entry in
                                  ``repro.kernels`` has a matching
                                  ``ref.*_math`` / ``*_ref`` oracle with
                                  the same positional signature (the
                                  contract PRs 4 and 9 kept by hand)

Everything traces against ``jax.ShapeDtypeStruct`` inputs — no data is
materialized and nothing is compiled, so the whole layer is a few
seconds of tracing.
"""
from __future__ import annotations

import ast
import inspect

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding

# no effectful primitive belongs inside the fused round scan
_EFFECT_PRIMS = ("callback", "infeed", "outfeed", "debug_print",
                 "host_local_array_to_global_array")


# ------------------------------------------------------------- harness --

def _tiny_fl(**kw):
    from repro.configs.base import FLConfig
    base = dict(num_clients=8, clients_per_round=4, cohorts=4,
                local_epochs=1, local_batch_size=2, seed=0)
    base.update(kw)
    return FLConfig(**base)


def config_matrix():
    """(label, FLConfig) per registered strategy, plus the telemetry and
    compressed-uplink planes on the default strategy — the row set every
    layer-2 rule traces."""
    from repro.core import strategies
    cfgs, seen = [], set()
    for name in strategies.names():
        cls = strategies.get(name)
        if cls in seen:            # registry aliases (ama / ama_fes)
            continue
        seen.add(cls)
        kw = {"algorithm": name}
        if name == "async_ama":
            kw.update(max_delay=3, p_delay=0.4)
        cfgs.append((name, _tiny_fl(**kw)))
    cfgs.append(("ama+extended_metrics",
                 _tiny_fl(algorithm="ama", extended_metrics=True)))
    cfgs.append(("ama+comm_q8", _tiny_fl(algorithm="ama", comm_plane="q8")))
    return cfgs


class TraceHarness:
    """Abstract inputs + the engine's real train loop for one config."""

    def __init__(self, fl, n_rounds: int = 2, model=None):
        from repro.configs.registry import ARCHS
        from repro.core import strategies
        from repro.core.round import init_state, make_round_step
        from repro.models.api import build_model
        self.fl = fl
        self.model = model or build_model(ARCHS["paper-cnn"])
        self.strategy = strategies.resolve(fl)
        self.n = n_rounds
        self.state = jax.eval_shape(
            lambda: init_state(self.model, fl, jax.random.PRNGKey(fl.seed),
                               self.strategy))
        C, b = fl.clients_per_round, fl.local_batch_size
        steps = 1
        sds = jax.ShapeDtypeStruct
        self.batch = {
            "image": sds((n_rounds, C, steps, b, 28, 28, 1), jnp.float32),
            "label": sds((n_rounds, C, steps, b), jnp.int32)}
        self.scheds = {
            "limited": sds((n_rounds, C), jnp.bool_),
            "delayed": sds((n_rounds, C), jnp.bool_),
            "delays": sds((n_rounds, C), jnp.int32),
            "data_sizes": sds((n_rounds, C), jnp.float32)}
        self._round_step = make_round_step(self.model, fl, self.strategy)

    def loop_args(self):
        args = [self.state, self.batch, self.scheds]
        if getattr(self.fl, "extended_metrics", False):
            args.append({"params": self.state["params"],
                         "aux": self.state["aux"]})
        return args

    def train_loop(self, donate: bool = True):
        from repro.core.round import make_train_loop
        return make_train_loop(self.model, self.fl, self.strategy,
                               per_round_batch=True, donate=donate)

    def lowered_text(self, donate: bool = True) -> str:
        return self.train_loop(donate).lower(*self.loop_args()).as_text()

    def jaxpr(self):
        return jax.make_jaxpr(self.train_loop())(*self.loop_args())

    def round_step_shapes(self):
        row = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
            (self.batch, self.scheds))
        return jax.eval_shape(self._round_step, self.state, row[0], row[1])


# --------------------------------------------------------------- rules --

def check_donation_aliasing(cfgs=None, *, donate: bool = True,
                            model=None) -> list[Finding]:
    """FED201: the lowering must report input-output aliasing for every
    donated params leaf (``tf.aliasing_output`` on the entry args)."""
    findings = []
    for label, fl in (cfgs or config_matrix()):
        h = TraceHarness(fl, model=model)
        txt = h.lowered_text(donate=donate)
        n_alias = txt.count("tf.aliasing_output")
        n_expected = len(jax.tree.leaves(h.state["params"]))
        if n_alias < n_expected:
            findings.append(Finding(
                rule="FED201", path=f"<trace:{label}>", line=0,
                message=(f"train_loop lowering aliases {n_alias} buffers "
                         f"but the donated carry has {n_expected} params "
                         "leaves — donation is declared but not taking "
                         "effect (the round carry would be copied every "
                         "chunk; check donate_argnums and that no extra "
                         "consumer keeps the carry alive)")))
    return findings


def _sub_jaxprs(eqn):
    """(maybe-closed, raw) jaxpr pairs referenced by one equation's
    params (pjit/scan/cond/custom_* all stash theirs differently)."""
    out = []
    vals = []
    for v in eqn.params.values():
        vals.extend(v if isinstance(v, (list, tuple)) else [v])
    for v in vals:
        if hasattr(v, "jaxpr") and hasattr(v, "eqns"):
            out.append((v, v.jaxpr))           # ClosedJaxpr
        elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
            out.append((v, v.jaxpr))           # ClosedJaxpr (no .eqns)
        elif hasattr(v, "eqns"):
            out.append((v, v))                 # raw Jaxpr
    return out


def _walk_scan_bodies(jaxpr):
    """Yield the (maybe-closed) body jaxpr of every scan, at any depth."""
    for eqn in jaxpr.eqns:
        for closed, raw in _sub_jaxprs(eqn):
            if eqn.primitive.name == "scan":
                yield closed
            yield from _walk_scan_bodies(raw)


def check_scan_effects(cfgs=None, *, model=None,
                       jaxpr_fn=None) -> list[Finding]:
    """FED202: no effectful primitives / JAX effects inside the fused
    round scan. ``jaxpr_fn(label, fl) -> jaxpr`` is injectable so the
    fixture tests can feed a deliberately dirty program."""
    findings = []
    for label, fl in (cfgs or config_matrix()):
        jx = (jaxpr_fn(label, fl) if jaxpr_fn
              else TraceHarness(fl, model=model).jaxpr())
        for body in _walk_scan_bodies(jx.jaxpr):
            effects = getattr(body, "effects", None) or getattr(
                getattr(body, "jaxpr", body), "effects", set())
            if effects:
                findings.append(Finding(
                    rule="FED202", path=f"<trace:{label}>", line=0,
                    message=(f"scan body carries JAX effects {effects} — "
                             "an effectful op inside the fused round "
                             "scan forces per-round host sync and "
                             "breaks donation/CSE isolation")))
            raw = getattr(body, "jaxpr", body)
            for eqn in raw.eqns:
                if any(tok in eqn.primitive.name for tok in _EFFECT_PRIMS):
                    findings.append(Finding(
                        rule="FED202", path=f"<trace:{label}>", line=0,
                        message=(f"effectful primitive "
                                 f"'{eqn.primitive.name}' inside the "
                                 "round scan body")))
    return findings


def check_carry_stability(cfgs=None, *, model=None,
                          step_fn=None) -> list[Finding]:
    """FED203: round_step(state, ...) must return a state with exactly
    the input's tree structure, shapes and dtypes. ``step_fn(h) ->
    (out_state_shapes, in_state_shapes)`` is injectable for fixtures."""
    findings = []
    for label, fl in (cfgs or config_matrix()):
        h = TraceHarness(fl, model=model)
        if step_fn is not None:
            out_state, in_state = step_fn(h)
        else:
            out_state = h.round_step_shapes()[0]
            in_state = h.state
        ti, to = jax.tree.structure(in_state), jax.tree.structure(out_state)
        if ti != to:
            findings.append(Finding(
                rule="FED203", path=f"<trace:{label}>", line=0,
                message=(f"round carry tree structure changes across a "
                         f"round: {ti} -> {to} — lax.scan and resume "
                         "both need a fixed carry")))
            continue
        for (keys, b), a in zip(
                jax.tree_util.tree_flatten_with_path(in_state)[0],
                jax.tree.leaves(out_state)):
            if a.shape != b.shape or a.dtype != b.dtype:
                findings.append(Finding(
                    rule="FED203", path=f"<trace:{label}>", line=0,
                    message=(f"carry leaf {jax.tree_util.keystr(keys)} "
                             f"unstable across a round: "
                             f"{b.shape}/{b.dtype} -> "
                             f"{a.shape}/{a.dtype}")))
    return findings


# kernel entries whose oracle does not follow the ``<base>_math`` /
# ``<base>_ref`` naming derivable from the kernel name
_ORACLE_CANDIDATES = ("{base}_math", "{base}_ref", "{name}_math",
                      "{name}_ref")


def _kernel_entries(module) -> list[tuple[str, list[str]]]:
    """Public top-level functions of ``module`` that dispatch a
    ``pallas_call``, with their positional parameter names (from the
    source AST — robust to jit wrappers)."""
    src = inspect.getsource(module)
    tree = ast.parse(src)
    out = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef) or node.name.startswith("_"):
            continue
        calls_pallas = any(
            isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
            and n.func.attr == "pallas_call"
            for n in ast.walk(node))
        if calls_pallas:
            pos = [a.arg for a in node.args.posonlyargs + node.args.args]
            out.append((node.name, pos))
    return out


def check_kernel_oracles(kernel_modules=None,
                         ref_module=None) -> list[Finding]:
    """FED204: every Pallas kernel entry must have a ref oracle with an
    identical positional signature. Both the kernel module list and the
    oracle module are injectable so a fixture can rename an oracle."""
    if kernel_modules is None:
        from repro.kernels import (ama_mix, flash_attention, rwkv6_scan,
                                   server_plane)
        kernel_modules = [ama_mix, flash_attention, rwkv6_scan,
                          server_plane]
    if ref_module is None:
        from repro.kernels import ref as ref_module
    findings = []
    for mod in kernel_modules:
        for name, kpos in _kernel_entries(mod):
            base = name[:-5] if name.endswith("_flat") else name
            cands = []
            for pat in _ORACLE_CANDIDATES:
                c = pat.format(base=base, name=name)
                if c not in cands:
                    cands.append(c)
            oracle = next((getattr(ref_module, c) for c in cands
                           if hasattr(ref_module, c)), None)
            where = f"{mod.__name__}.{name}"
            if oracle is None:
                findings.append(Finding(
                    rule="FED204", path=f"<kernel:{where}>", line=0,
                    message=(f"no oracle for Pallas kernel '{name}' — "
                             f"expected one of {cands} in "
                             f"{getattr(ref_module, '__name__', 'ref')} "
                             "(the kernel's only correctness ground "
                             "truth; see kernels/ref.py)")))
                continue
            sig = inspect.signature(oracle)
            opos = [p.name for p in sig.parameters.values()
                    if p.kind in (p.POSITIONAL_ONLY,
                                  p.POSITIONAL_OR_KEYWORD)]
            if opos != kpos:
                findings.append(Finding(
                    rule="FED204", path=f"<kernel:{where}>", line=0,
                    message=(f"oracle '{oracle.__name__}' positional "
                             f"signature {opos} does not match kernel "
                             f"'{name}' positional signature {kpos} — "
                             "parity tests would silently compare "
                             "misaligned arguments")))
    return findings


JAXPR_RULES = {
    "FED201": check_donation_aliasing,
    "FED202": check_scan_effects,
    "FED203": check_carry_stability,
    "FED204": check_kernel_oracles,
}


def run(select=None) -> list[Finding]:
    """All (selected) layer-2 rules over the real registries. The config
    matrix is traced once and shared by the rules that need it."""
    findings = []
    selected = [rid for rid in JAXPR_RULES
                if select is None or rid in select]
    if not selected:
        return findings
    cfgs = config_matrix() if any(r != "FED204" for r in selected) else None
    for rid in selected:
        if rid == "FED204":
            findings.extend(check_kernel_oracles())
        else:
            findings.extend(JAXPR_RULES[rid](cfgs))
    return findings
