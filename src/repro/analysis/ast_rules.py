"""Layer 1: AST invariant rules over host-side Python.

Each rule encodes an invariant this repo has actually shipped a fix for
(see README "Static analysis & invariants"):

  FED101 use-after-donate        a buffer passed to a ``donate_argnums``
                                 jit is read again before reassignment —
                                 donated storage is invalid after the
                                 call (the engine/serving planes donate
                                 the round carry and the KV pool)
  FED102 host-nondeterminism     ``np.random.*`` / ``time.*`` clocks /
                                 stdlib ``random`` inside traced code —
                                 baked in as a trace-time constant, it
                                 silently breaks scan==loop==resume
                                 bit-identity (the PR 7 timing fictions)
  FED103 scan-side-effect        Python side effects (print/IO/logging/
                                 closure mutation) in a ``lax.scan`` /
                                 ``fori/while/cond`` body — they run
                                 once at trace time, not per round
  FED104 kernel-side-effect      same, inside a ``pallas_call`` kernel
  FED105 bare-except             ``except:`` catches KeyboardInterrupt/
                                 SystemExit and hides real failures
  FED106 swallowed-exception     an except body that is only ``pass`` in
                                 checkpoint/prefetcher paths — a
                                 half-written checkpoint or a dead
                                 staging thread must surface, not vanish

Heuristics are intentionally conservative (a finding should be worth a
human's time): tracing contexts are functions syntactically passed to /
decorated with jit/vmap/grad/scan/pallas_call (nested defs inherit),
and use-after-donate is a straight-line, same-block analysis.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding

# call targets whose function-valued arguments are traced
_TRACERS = {"jit", "vmap", "pmap", "grad", "value_and_grad", "checkpoint",
            "remat", "eval_shape", "make_jaxpr", "scan", "fori_loop",
            "while_loop", "cond", "switch", "pallas_call", "custom_vjp",
            "custom_jvp"}
_LOOP_BODY = {"scan", "fori_loop", "while_loop", "cond", "switch"}

# the legitimate host plane: numpy RNG / clocks ARE the contract here
# (counter-based schedule hashes, perf timers closed by block_until_ready)
_FED102_ALLOW = ("repro/env/", "repro/obs/", "env/base.py")

# FED106 scope: checkpoint writers and the staging prefetcher
_FED106_PATHS = ("checkpoint", "pipeline")

_NONDET_PREFIXES = ("np.random.", "numpy.random.", "random.",
                    "secrets.", "uuid.")
_NONDET_EXACT = {"time.time", "time.perf_counter", "time.monotonic",
                 "time.time_ns", "datetime.now", "datetime.datetime.now",
                 "datetime.utcnow"}
_EFFECT_PREFIXES = ("logging.", "os.", "sys.", "shutil.", "json.dump",
                    "np.save", "numpy.save", "pickle.")
_EFFECT_BARE = {"print", "open", "input", "breakpoint"}
_MUTATORS = {"append", "extend", "insert", "update", "add", "put",
             "write", "writelines", "setdefault", "remove", "clear"}


def _walk_shallow(node):
    """ast.walk that does not descend into nested function definitions
    (straight-line analyses must not attribute a closure's statements to
    the enclosing block)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            stack.extend(ast.iter_child_nodes(n))


def _roots(ma, contexts: set) -> set:
    """Outermost members of a context set (nested defs are covered by
    walking their root once)."""
    return {c for c in contexts
            if ma._enclosing_function(c) not in contexts}


def dotted(node: ast.AST) -> str | None:
    """'a.b.c' for a pure Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _last(dot: str | None) -> str | None:
    return dot.rsplit(".", 1)[-1] if dot else None


class ModuleAnalysis:
    """One parse of one file, shared by every AST rule."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.parent: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
        self._funcdefs = [n for n in ast.walk(self.tree)
                          if isinstance(n, (ast.FunctionDef,
                                            ast.AsyncFunctionDef))]
        self.scan_bodies = set()
        self.pallas_kernels = set()
        self.traced = set()
        self._collect_contexts()

    # ---------------------------------------------------- scope helpers --
    def _enclosing_function(self, node: ast.AST):
        cur = self.parent.get(node)
        while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            cur = self.parent.get(cur)
        return cur

    def _resolve_func_arg(self, arg: ast.AST, at: ast.AST):
        """The FunctionDef/Lambda a callable-valued argument refers to
        (unwrapping functools.partial), or None."""
        if isinstance(arg, ast.Lambda):
            return arg
        if isinstance(arg, ast.Call) and _last(dotted(arg.func)) == "partial":
            return (self._resolve_func_arg(arg.args[0], at)
                    if arg.args else None)
        name = dotted(arg)
        if name is None or "." in name:
            return None
        # nearest def with that name: same enclosing function first,
        # then any scope outward (module-level kernels referenced from
        # inside wrappers resolve here)
        encl = self._enclosing_function(at)
        cands = [f for f in self._funcdefs if f.name == name]
        for f in cands:
            if self._enclosing_function(f) is encl:
                return f
        return cands[0] if cands else None

    def _mark(self, root, bucket: set):
        bucket.add(root)
        self.traced.add(root)
        for sub in ast.walk(root):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)) and sub is not root:
                bucket.add(sub)
                self.traced.add(sub)

    def _collect_contexts(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                last = _last(dotted(node.func))
                if last not in _TRACERS:
                    continue
                for arg in node.args:
                    fn = self._resolve_func_arg(arg, node)
                    if fn is None:
                        continue
                    if last == "pallas_call":
                        self._mark(fn, self.pallas_kernels)
                    elif last in _LOOP_BODY:
                        self._mark(fn, self.scan_bodies)
                    else:
                        self._mark(fn, self.traced)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if self._is_jit_decorator(dec):
                        self._mark(node, self.traced)

    @staticmethod
    def _is_jit_decorator(dec: ast.AST) -> bool:
        if _last(dotted(dec)) == "jit":
            return True
        if isinstance(dec, ast.Call):
            if _last(dotted(dec.func)) == "jit":
                return True
            # functools.partial(jax.jit, static_argnames=...)
            if (_last(dotted(dec.func)) == "partial" and dec.args
                    and _last(dotted(dec.args[0])) == "jit"):
                return True
        return False

    def _locals_of(self, fn) -> set:
        """Names bound inside ``fn`` (args + any store), nested included
        — conservative: a mutation only fires when the base name cannot
        be local."""
        out = set()
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.Lambda)):
            a = fn.args
            for arg in (a.posonlyargs + a.args + a.kwonlyargs):
                out.add(arg.arg)
            if a.vararg:
                out.add(a.vararg.arg)
            if a.kwarg:
                out.add(a.kwarg.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(
                    node.ctx, (ast.Store, ast.Del)):
                out.add(node.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.add(node.name)
        return out


# ------------------------------------------------------------------ rules --

def fed101_use_after_donate(ma: ModuleAnalysis) -> list[Finding]:
    """Donated buffers read after the donating call (same block)."""
    findings = []
    donors = _donating_callables(ma)
    if not donors:
        return findings
    for fn in ma._funcdefs:
        _scan_block_for_donation(ma, fn.body, donors, findings)
    _scan_block_for_donation(ma, ma.tree.body, donors, findings)
    return findings


def _donating_callables(ma: ModuleAnalysis) -> dict[str, tuple]:
    """dotted callable name -> (donated positional indices, donated arg
    names) for every ``X = jax.jit(..., donate_argnums=...)`` binding."""
    donors = {}
    for node in ast.walk(ma.tree):
        if not (isinstance(node, ast.Assign) and isinstance(node.value,
                                                            ast.Call)):
            continue
        call = node.value
        if _last(dotted(call.func)) != "jit":
            continue
        idxs, names = _donation_spec(call)
        if not idxs and not names:
            continue
        for tgt in node.targets:
            name = dotted(tgt)
            if name:
                donors[name] = (idxs, names)
    return donors


def _donation_spec(call: ast.Call) -> tuple[set, set]:
    idxs, names = set(), set()
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            idxs |= set(_const_ints(kw.value))
        elif kw.arg == "donate_argnames":
            names |= set(_const_strs(kw.value))
    return idxs, names


def _const_ints(node) -> list[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)]
    return []


def _const_strs(node) -> list[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def _assigned_names(stmt) -> set:
    """Dotted names (re)bound by a statement — its call's own Assign
    targets count, so ``logits, kv.pool = self._pf(..., kv.pool, ...)``
    is the SAFE donation idiom."""
    out = set()
    if isinstance(stmt, ast.Assign):
        tgts = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        tgts = [stmt.target]
    elif isinstance(stmt, ast.For):
        tgts = [stmt.target]
    else:
        return out
    for t in tgts:
        for el in ast.walk(t):
            d = dotted(el)
            if d:
                out.add(d)
    return out


def _scan_block_for_donation(ma, body: list, donors: dict,
                             findings: list) -> None:
    """Linear pass over one statement list; recurses into nested blocks
    with the same straight-line discipline. Only SIMPLE statements are
    donation sites here: a call buried in a while/if/def is analyzed in
    its own block, where the in-statement reassignment idiom
    (``logits, cache = pf(..., cache)``) is visible."""
    for i, stmt in enumerate(body):
        calls = ([] if getattr(stmt, "body", None) else
                 [n for n in _walk_shallow(stmt) if isinstance(n, ast.Call)])
        for call in calls:
            spec = donors.get(dotted(call.func) or "")
            if spec is None:
                continue
            donated = []
            idxs, names = spec
            for j, arg in enumerate(call.args):
                d = dotted(arg)
                if d and j in idxs:
                    donated.append(d)
            for kw in call.keywords:
                d = dotted(kw.value)
                if d and kw.arg in names:
                    donated.append(d)
            if not donated:
                continue
            live = set(donated) - _assigned_names(stmt)
            for later in body[i + 1:]:
                if not live:
                    break
                if isinstance(later, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    continue        # closures run interleaved with rebinds
                for node in _walk_shallow(later):
                    d = dotted(node)
                    if d in live and isinstance(getattr(node, "ctx", None),
                                                ast.Load):
                        findings.append(Finding(
                            rule="FED101", path=ma.path, line=node.lineno,
                            col=node.col_offset,
                            message=(f"'{d}' was donated to "
                                     f"'{dotted(call.func)}' on line "
                                     f"{call.lineno} and is read again "
                                     "before reassignment — donated "
                                     "buffers are invalidated by XLA")))
                        live.discard(d)
                live -= _assigned_names(later)
        # recurse into compound statements (fresh straight-line blocks);
        # nested defs get their own pass via ma._funcdefs
        for sub in (getattr(stmt, "body", []), getattr(stmt, "orelse", []),
                    getattr(stmt, "finalbody", []),
                    *(h.body for h in getattr(stmt, "handlers", []))):
            if sub and not isinstance(stmt, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                _scan_block_for_donation(ma, sub, donors, findings)


def fed102_host_nondeterminism(ma: ModuleAnalysis) -> list[Finding]:
    if any(allow in ma.path.replace("\\", "/") for allow in _FED102_ALLOW):
        return []
    findings = []
    for ctx in _roots(ma, ma.traced):
        for node in ast.walk(ctx):
            if not isinstance(node, ast.Call):
                continue
            dot = dotted(node.func)
            if dot is None:
                continue
            hit = (dot in _NONDET_EXACT
                   or any(dot.startswith(p) for p in _NONDET_PREFIXES))
            if hit:
                findings.append(Finding(
                    rule="FED102", path=ma.path, line=node.lineno,
                    col=node.col_offset,
                    message=(f"'{dot}' inside traced code — evaluated "
                             "once at trace time (a baked-in constant), "
                             "breaking scan==loop==resume determinism; "
                             "use jax.random with a threaded key, or "
                             "stage host-side")))
    return findings


def _enclosing_traced_locals(ma, ctx) -> set:
    """Names bound by traced functions ENCLOSING ``ctx`` — a fori/scan
    body nested inside a pallas kernel stores into the kernel's output
    refs (``y_ref[...] = ...``), which is the kernel's write idiom, not
    a host side effect."""
    out = set()
    cur = ma._enclosing_function(ctx)
    while cur is not None:
        if cur in ma.traced:
            out |= ma._locals_of(cur)
        cur = ma._enclosing_function(cur)
    return out


def _side_effects_in(ma, contexts: set, rule: str,
                     where: str) -> list[Finding]:
    findings = []
    for ctx in _roots(ma, contexts):
        local = ma._locals_of(ctx)
        store_ok = local | _enclosing_traced_locals(ma, ctx)
        for node in ast.walk(ctx):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                findings.append(Finding(
                    rule=rule, path=ma.path, line=node.lineno,
                    col=node.col_offset,
                    message=f"global/nonlocal rebinding inside {where}"))
            elif isinstance(node, ast.Call):
                dot = dotted(node.func)
                if dot is None:
                    continue
                msg = None
                if dot in _EFFECT_BARE or any(
                        dot.startswith(p) for p in _EFFECT_PREFIXES):
                    msg = f"'{dot}' is a host side effect"
                elif ("." in dot and dot.rsplit(".", 1)[1] in _MUTATORS
                        and dot.split(".", 1)[0] not in local):
                    msg = (f"'{dot}' mutates a closure/global object")
                if msg:
                    findings.append(Finding(
                        rule=rule, path=ma.path, line=node.lineno,
                        col=node.col_offset,
                        message=(f"{msg} inside {where} — it runs once "
                                 "at trace time, not per iteration "
                                 "(use scan ys / io_callback for real "
                                 "telemetry)")))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                tgts = (node.targets if isinstance(node, ast.Assign)
                        else [node.target])
                for t in tgts:
                    if isinstance(t, ast.Subscript):
                        base = dotted(t.value)
                        if base and base.split(".", 1)[0] not in store_ok:
                            findings.append(Finding(
                                rule=rule, path=ma.path, line=node.lineno,
                                col=node.col_offset,
                                message=(f"subscript store into closure "
                                         f"'{base}' inside {where} — a "
                                         "trace-time mutation, not a "
                                         "per-iteration effect")))
    return findings


def fed103_scan_side_effect(ma: ModuleAnalysis) -> list[Finding]:
    return _side_effects_in(ma, ma.scan_bodies, "FED103",
                            "a lax.scan/loop body")


def fed104_kernel_side_effect(ma: ModuleAnalysis) -> list[Finding]:
    return _side_effects_in(ma, ma.pallas_kernels, "FED104",
                            "a pallas_call kernel")


def fed105_bare_except(ma: ModuleAnalysis) -> list[Finding]:
    findings = []
    for node in ast.walk(ma.tree):
        if isinstance(node, ast.ExceptHandler) and node.type is None:
            findings.append(Finding(
                rule="FED105", path=ma.path, line=node.lineno,
                col=node.col_offset,
                message=("bare 'except:' catches KeyboardInterrupt/"
                         "SystemExit — name the exceptions")))
    return findings


def fed106_swallowed_exception(ma: ModuleAnalysis) -> list[Finding]:
    path = ma.path.replace("\\", "/")
    if not any(p in path for p in _FED106_PATHS):
        return []
    findings = []
    for node in ast.walk(ma.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        body = [s for s in node.body
                if not (isinstance(s, ast.Expr)
                        and isinstance(s.value, ast.Constant))]
        if all(isinstance(s, (ast.Pass, ast.Continue)) for s in body):
            findings.append(Finding(
                rule="FED106", path=ma.path, line=node.lineno,
                col=node.col_offset,
                message=("exception swallowed in a checkpoint/prefetcher "
                         "path — a half-written checkpoint or dead "
                         "staging thread must surface (re-raise, or "
                         "propagate through the consumer queue)")))
    return findings


AST_RULES = {
    "FED101": fed101_use_after_donate,
    "FED102": fed102_host_nondeterminism,
    "FED103": fed103_scan_side_effect,
    "FED104": fed104_kernel_side_effect,
    "FED105": fed105_bare_except,
    "FED106": fed106_swallowed_exception,
}


def run_file(path: str, source: str, select=None) -> list[Finding]:
    """All (selected) AST rules over one file, suppressions applied."""
    from repro.analysis import suppress
    ma = ModuleAnalysis(path, source)
    findings = []
    for rule_id, rule in AST_RULES.items():
        if select is None or rule_id in select:
            findings.extend(rule(ma))
    return suppress.apply(findings, source, path)
