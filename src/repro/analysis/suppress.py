"""Per-line fedlint suppressions.

Syntax (inline on the flagged line, or on a standalone comment line
immediately above it)::

    risky_call()   # fedlint: disable=FED102 — staged host-side, pure in t
    # fedlint: disable=FED103,FED104 — telemetry ys, not a side effect
    flagged_line()

The justification after the dash is REQUIRED: a justified suppression
silences the rule; a bare ``# fedlint: disable=FED102`` still silences
it but emits FED100 (suppression-without-justification) in its place,
so "why is this OK" can never silently rot out of the code. Rule lists
are comma-separated; ``all`` matches every rule.
"""
from __future__ import annotations

import re

from repro.analysis.findings import Finding

# "# fedlint: disable=FED101,FED102 — why this is fine"
# separator: em/en dash, or 1-2 ASCII hyphens surrounded by whitespace
_RE = re.compile(
    r"#\s*fedlint:\s*disable=([A-Za-z0-9_,\s]+?)"
    r"(?:\s+(?:[—–]|--?)\s*(\S.*?))?\s*$")


def parse(source: str) -> dict[int, dict]:
    """line number (1-based) -> {"rules": set, "justification": str|None,
    "standalone": bool} for every suppression comment in ``source``."""
    out = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        just = m.group(2)
        standalone = line.split("#", 1)[0].strip() == ""
        out[i] = {"rules": rules, "justification": just,
                  "standalone": standalone}
    return out


def apply(findings: list[Finding], source: str, path: str) -> list[Finding]:
    """Mark suppressed findings in place; append FED100 findings for
    suppression comments that carry no justification. Returns the
    (possibly extended) list."""
    supp = parse(source)
    # a standalone suppression comment governs the NEXT line
    by_target: dict[int, dict] = {}
    for ln, ent in supp.items():
        by_target[ln + 1 if ent["standalone"] else ln] = ent
    for f in findings:
        ent = by_target.get(f.line)
        if ent and (f.rule in ent["rules"] or "all" in ent["rules"]):
            f.suppressed = True
            f.justification = ent["justification"]
    out = list(findings)
    for ln, ent in supp.items():
        if not ent["justification"]:
            out.append(Finding(
                rule="FED100", path=path, line=ln,
                message=("suppression without justification — write "
                         "'# fedlint: disable=RULE — <why this is OK>'")))
    return out
