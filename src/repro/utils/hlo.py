"""HLO post-processing for the roofline analysis.

Parses the optimized HLO text of a compiled executable and sums the operand
bytes of every cross-device collective. ``cost_analysis()`` reports FLOPs and
HBM bytes but NOT collective traffic, so this is the third roofline term.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  bf16[16,4096,512]{2,1,0}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)   # op kind -> #ops
    bytes_: dict = field(default_factory=dict)   # op kind -> total output bytes

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())

    def summary(self) -> str:
        parts = [
            f"{k}: n={self.counts[k]} bytes={self.bytes_[k]:,}"
            for k in sorted(self.counts)
        ]
        return "; ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in an HLO module.

    HLO lines look like::

        %ag = bf16[512,4096]{1,0} all-gather(%p), replica_groups=...

    We take the *result* shape (left of '='), which for all-gather is the
    gathered size (upper bound on the wire traffic per participant ring) and
    for all-reduce equals the tensor size (ring all-reduce moves ~2x, we keep
    the raw tensor size and note the convention in EXPERIMENTS.md).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "  %name = <shape(s)> <op>(" ; op may be e.g. all-reduce-start
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([a-z0-9\-]+)\(", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        kind = None
        for c in COLLECTIVE_OPS:
            if op == c or op.startswith(c + "-"):  # -start/-done variants
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting start/done pairs
        b = _shape_bytes(shape_str)
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.bytes_[kind] = stats.bytes_.get(kind, 0) + b
    return stats


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
