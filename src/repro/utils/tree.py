"""Pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_lerp(a, b, w):
    """(1 - w) * a + w * b, leafwise (w may be a traced scalar)."""
    return jax.tree.map(lambda ai, bi: (1.0 - w) * ai + w * bi, a, b)


def tree_weighted_sum(trees, weights):
    """sum_k weights[k] * trees[k]; trees is a list of like pytrees."""
    out = tree_scale(trees[0], weights[0])
    for t, w in zip(trees[1:], weights[1:]):
        out = jax.tree.map(lambda o, x, w=w: o + w * x, out, t)
    return out


def tree_select(pred, a, b):
    """where(pred, a, b) leafwise; pred is a scalar bool (traced ok)."""
    return jax.tree.map(lambda ai, bi: jnp.where(pred, ai, bi), a, b)


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_l2sq(tree) -> jax.Array:
    """Sum of squared L2 norms over all leaves (scalar)."""
    leaves = jax.tree.leaves(tree)
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


def tree_allfinite(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    ok = jnp.array(True)
    for x in leaves:
        if jnp.issubdtype(x.dtype, jnp.floating):
            ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(x)))
    return ok


def tree_stack(trees):
    """Stack a list of like pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n: int):
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]
