"""Serving engines: per-token loop (fixed) and paged continuous batching.

``LoopEngine`` is the seed launcher's lockstep decode made correct for
variable-length prompts: every row feeds its OWN prompt token while it
still has prompt left and its last sampled token afterwards, so padded
positions never enter the KV cache. With ``prefill_chunk > 0`` (and a
model exposing ``prefill``) the shared prompt prefix [0, min_len-1) is
prefilled in jitted chunks — one dispatch per chunk instead of per
token — bit-identically to the per-token path.

``PagedEngine`` is the production plane: requests are admitted by the
FIFO token-budget ``Scheduler`` into fixed decode slots, their prompts
chunk-prefilled (B=1) straight into the shared ``KVPool``, and all
active slots decode in lockstep through one jitted
``decode_step_paged``. Finished requests free their blocks between
steps and the freed slot/blocks are reused by the next admission —
continuous batching. Requests of different lengths pay for their own
ring (ceil(ring/block_size) blocks), not the batch max.

Decode runs in MULTI-STEP BURSTS: under greedy decoding every
completion time is known in advance (len(generated) == max_new), so
between scheduling events the engine dispatches one ``lax.scan`` of
decode steps — argmax feedback stays on device — instead of one jit
call per token. Burst lengths are rounded down to powers of two (capped
at 32) so at most six scan variants ever compile. Scan-of-decode-step
is bit-identical to the per-token loop (same contract the training
engine's scan relies on), so bursts do not perturb the served tokens.

All timings use perf_counter spans closed AFTER the host transfer of
the step's argmax (which blocks on the step), so per-request latency
percentiles are honest — same discipline as obs.timing.sync_time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import PAD_POS
from repro.serve.kv_pool import KVPool
from repro.serve.scheduler import Request, Scheduler


def latency_percentiles(seconds: list[float]) -> dict:
    if not seconds:
        return {"p50_ms": None, "p95_ms": None, "p99_ms": None}
    a = np.asarray(seconds, np.float64) * 1e3
    return {f"p{q}_ms": round(float(np.percentile(a, q)), 2)
            for q in (50, 95, 99)}


def _result(req: Request) -> dict:
    return {
        "id": req.rid,
        "tokens": list(req.prompt) + [int(t) for t in req.generated],
        "new_tokens": len(req.generated),
        "queue_s": req.admit_t - req.submit_t,
        "prefill_s": req.prefill_s,
        "decode_s": req.done_t - req.admit_t - req.prefill_s,
        "total_s": req.done_t - req.submit_t,
    }


def _summary(results: list[dict], wall_s: float) -> dict:
    new = sum(r["new_tokens"] for r in results)
    return {"requests": len(results), "new_tokens": new,
            "wall_s": round(wall_s, 4),
            "tokens_per_s": round(new / wall_s, 2) if wall_s > 0 else 0.0,
            **latency_percentiles([r["total_s"] for r in results])}


def _ring_len(cfg, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window \
        else max_len


class LoopEngine:
    """Lockstep decode with per-request prompt lengths (+ optional
    jitted chunked prefill of the shared prefix)."""

    def __init__(self, model, params, prefill_chunk: int = 0):
        self.model, self.params = model, params
        self.prefill_chunk = int(prefill_chunk) \
            if model.prefill is not None else 0
        self._step = jax.jit(model.decode_step)
        self._pf = jax.jit(model.prefill) if self.prefill_chunk else None
        self.last_summary: dict | None = None

    def _init_cache(self, B: int, max_len: int):
        model = self.model
        if model.cfg.family == "audio":
            fe = jnp.zeros((B, model.cfg.encoder_seq, model.cfg.d_model),
                           jnp.dtype(model.cfg.dtype))
            return model.init_decode_cache(self.params, fe, max_len)
        return model.init_decode_cache(self.params, B, max_len)

    def run(self, requests: list[Request]) -> list[dict]:
        reqs = list(requests)
        B = len(reqs)
        t_start = time.perf_counter()
        for r in reqs:
            r.submit_t = r.admit_t = t_start       # all admitted at once
            r.generated = []
        lens = [r.prompt_len for r in reqs]
        max_len = max(r.prompt_len + r.max_new for r in reqs) + 1
        cache = self._init_cache(B, max_len)
        params = self.params

        t0 = 0
        if self.prefill_chunk:
            # jitted chunked prefill of the SHARED prefix [0, min_len-1);
            # per-row prompt tails + generation stay in the token loop
            c = min(self.prefill_chunk, _ring_len(self.model.cfg, max_len))
            end = min(lens) - 1
            t_pf = time.perf_counter()
            while t0 < end:
                n = min(c, end - t0)
                toks = np.zeros((B, c), np.int32)
                poss = np.full((B, c), PAD_POS, np.int32)
                for b, r in enumerate(reqs):
                    toks[b, :n] = r.prompt[t0:t0 + n]
                poss[:, :n] = np.arange(t0, t0 + n, dtype=np.int32)
                logits, cache = self._pf(params, jnp.asarray(toks),
                                         jnp.asarray(poss), cache)
                t0 += n
            jax.block_until_ready(cache)
            for r in reqs:
                r.prefill_s = time.perf_counter() - t_pf

        T = max(r.prompt_len + r.max_new for r in reqs) - 1
        tok = np.zeros((B,), np.int32)
        for t in range(t0, T):
            for b, r in enumerate(reqs):
                if t < lens[b]:
                    tok[b] = r.prompt[t]
                else:
                    tok[b] = r.generated[min(t - lens[b],
                                             len(r.generated) - 1)]
            # NOTE: tok is mutated per step while prefill steps run
            # async (no sync until a row samples) — hand each step its
            # own copy so the CPU backend can't zero-copy-alias a
            # buffer we are about to overwrite
            logits, cache = self._step(params, jnp.asarray(tok.copy()),
                                       jnp.full((B,), t, jnp.int32), cache)
            if t < min(lens) - 1:
                continue            # pure prefill: no row samples yet
            args = np.asarray(jnp.argmax(logits, axis=-1))   # blocks
            now = time.perf_counter()
            for b, r in enumerate(reqs):
                if t >= lens[b] - 1 and len(r.generated) < r.max_new:
                    r.generated.append(int(args[b]))
                    if len(r.generated) == r.max_new:
                        r.done_t = now
        results = [_result(r) for r in reqs]
        self.last_summary = _summary(results, time.perf_counter() - t_start)
        return results


class PagedEngine:
    """Continuous batching over a shared paged KV pool (attention
    families only — ssm/hybrid have recurrent state, not a KV ring)."""

    def __init__(self, model, params, *, max_slots: int = 4,
                 block_size: int = 8, max_batch_tokens: int = 0,
                 prefill_chunk: int = 8, num_blocks: int | None = None):
        if model.prefill_paged is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged serving path "
                f"(use LoopEngine)")
        self.model, self.params = model, params
        self.max_slots = int(max_slots)
        self.block_size = int(block_size)
        self.max_batch_tokens = int(max_batch_tokens)
        self.prefill_chunk = int(prefill_chunk)
        self.num_blocks = num_blocks
        # pool buffers are donated: the engine always replaces kv.pool
        # with the returned tree, so XLA updates the blocks in place
        # instead of copying the whole pool every dispatch
        self._pf = jax.jit(model.prefill_paged, donate_argnums=(3,))
        self._bursts: dict[int, object] = {}      # burst length -> jitted
        self.last_summary: dict | None = None
        self.scheduler: Scheduler | None = None
        self.kv: KVPool | None = None

    _MAX_BURST = 32

    def _burst(self, n: int):
        """Jitted scan of ``n`` decode steps with on-device greedy
        feedback. Returns (sampled (n, S) int32, new pool)."""
        if n not in self._bursts:
            step = self.model.decode_step_paged

            def fn(params, tok, pos, pool, table, lw):
                def body(carry, _):
                    tok, pos, pool = carry
                    logits, pool = step(params, tok, pos, pool, table, lw)
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    return (tok, pos + 1, pool), tok
                (tok, pos, pool), toks = jax.lax.scan(
                    body, (tok, pos, pool), None, length=n)
                return toks, pool
            self._bursts[n] = jax.jit(fn, donate_argnums=(3,))
        return self._bursts[n]

    def run(self, requests: list[Request]) -> list[dict]:
        cfg = self.model.cfg
        params = self.params
        reqs = list(requests)
        rings = {r.rid: _ring_len(cfg, r.prompt_len + r.max_new + 1)
                 for r in reqs}
        S = self.max_slots
        bs = self.block_size
        MB = max(-(-lw // bs) for lw in rings.values())
        NB = self.num_blocks or 1 + S * MB
        kv = self.kv = KVPool(self.model, NB, bs)
        sched = self.scheduler = Scheduler(self.max_batch_tokens)
        c = max(1, min(self.prefill_chunk, min(rings.values())))

        slot_rid: list[int | None] = [None] * S
        table = np.zeros((S, MB), np.int32)
        lw = np.ones((S,), np.int32)
        pos = np.zeros((S,), np.int32)
        tok = np.zeros((S,), np.int32)
        blocks_of: dict[int, list[int]] = {}
        by_rid = {r.rid: r for r in reqs}

        t_start = time.perf_counter()
        for r in reqs:
            r.submit_t = t_start
            r.generated = []
            sched.submit(r)

        def can_place(req):
            return (None in slot_rid
                    and kv.can_alloc(kv.blocks_for(rings[req.rid])))

        def admit_all():
            # waves until the queue head no longer fits (a wave's own
            # max_new==1 completions can free slots for the next wave)
            while admit_wave():
                pass

        def admit_wave() -> bool:
            # admit a WAVE: every head-of-queue request that fits right
            # now, then prefill the whole wave in lockstep chunks — one
            # dispatch per chunk for the wave, not per request
            wave: list[tuple[int, Request]] = []
            while True:
                req = sched.try_admit(can_place=can_place)
                if req is None:
                    break
                s = slot_rid.index(None)
                nblk = kv.blocks_for(rings[req.rid])
                blocks_of[req.rid] = blocks = kv.alloc(nblk)
                slot_rid[s] = req.rid
                sched.record_slot(req.rid, s)
                table[s, :] = 0
                table[s, :nblk] = blocks
                lw[s] = rings[req.rid]
                req.admit_t = time.perf_counter()
                wave.append((s, req))
            if not wave:
                return False
            # ---- jitted chunked prefill into the shared pool. Rows that
            # run out of prompt before the wave's longest become all-PAD
            # (predicated no-op writes); each row's first sampled token
            # comes from the chunk holding its last prompt position.
            W = len(wave)
            slots_w = [s for s, _ in wave]
            t_rows = jnp.asarray(table[slots_w])
            l_rows = jnp.asarray(lw[slots_w])
            maxP = max(r.prompt_len for _, r in wave)
            first_tok = {}
            for t0 in range(0, maxP, c):
                toks = np.zeros((W, c), np.int32)
                poss = np.full((W, c), PAD_POS, np.int32)
                for w, (_, r) in enumerate(wave):
                    n = min(c, r.prompt_len - t0)
                    if n > 0:
                        toks[w, :n] = r.prompt[t0:t0 + n]
                        poss[w, :n] = np.arange(t0, t0 + n, dtype=np.int32)
                logits, kv.pool = self._pf(
                    params, jnp.asarray(toks), jnp.asarray(poss),
                    kv.pool, t_rows, l_rows)
                args = np.asarray(jnp.argmax(logits, axis=-1))   # blocks
                for w, (_, r) in enumerate(wave):
                    last = r.prompt_len - 1 - t0
                    if 0 <= last < c:
                        first_tok[r.rid] = int(args[w, last])
            now = time.perf_counter()
            for s, req in wave:
                req.prefill_s = now - req.admit_t
                req.generated.append(first_tok[req.rid])
                pos[s] = req.prompt_len
                tok[s] = first_tok[req.rid]
                if len(req.generated) >= req.max_new:
                    finish(s, now)
            return True

        def finish(s, now):
            rid = slot_rid[s]
            req = by_rid[rid]
            req.done_t = now
            kv.free(blocks_of.pop(rid))
            sched.release(req)
            slot_rid[s] = None
            table[s, :] = 0
            lw[s] = 1
            pos[s] = 0
            tok[s] = 0

        results_order = [r.rid for r in reqs]
        admit_all()
        while any(s is not None for s in slot_rid) or sched.pending:
            if all(s is None for s in slot_rid):
                # nothing in flight yet the head cannot be placed: the
                # request cannot ever fit this pool
                req = sched.queue[0]
                raise RuntimeError(
                    f"request {req.rid} needs "
                    f"{kv.blocks_for(rings[req.rid])} blocks; pool has "
                    f"{kv.num_blocks - 1} total")
            # steps until the next scheduling event are known exactly
            # under greedy decoding — burst them in one scan dispatch
            to_event = min(by_rid[rid].max_new - len(by_rid[rid].generated)
                           for rid in slot_rid if rid is not None)
            n = 1
            while n * 2 <= min(to_event, self._MAX_BURST):
                n *= 2
            toks, kv.pool = self._burst(n)(
                params, jnp.asarray(tok), jnp.asarray(pos),
                kv.pool, jnp.asarray(table), jnp.asarray(lw))
            args = np.asarray(toks)                          # blocks
            now = time.perf_counter()
            for s in range(S):
                if slot_rid[s] is None:
                    continue
                req = by_rid[slot_rid[s]]
                req.generated.extend(int(t) for t in args[:, s])
                pos[s] += n
                tok[s] = int(args[-1, s])
                if len(req.generated) >= req.max_new:
                    finish(s, now)
            admit_all()

        results = [_result(by_rid[rid]) for rid in results_order]
        self.last_summary = _summary(results, time.perf_counter() - t_start)
        return results
