"""Continuous-batching scheduler: FIFO admission under a token budget.

The engine calls ``try_admit`` between decode steps with the resources
it currently has free (a decode slot, KV blocks); the scheduler only
ever offers the HEAD of the queue — no request can be overtaken, so no
request starves (gated in tests/test_serve_plane.py). The token budget
bounds the total in-flight footprint sum(prompt_len + max_new) the way
a real deployment bounds KV memory.

Invariant counters (``admitted_order``, ``peak_inflight_tokens``,
``slot_history``) exist for the tests and the serving telemetry rows —
they are not consulted by the policy itself.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class Request:
    """One decode request. ``prompt`` is a plain list/1-D array of int
    token ids (per-request length — nothing is padded here)."""
    rid: int
    prompt: list
    max_new: int
    # engine-filled runtime state / timings (seconds, perf_counter span)
    generated: list = field(default_factory=list)
    submit_t: float = 0.0
    admit_t: float = 0.0
    done_t: float = 0.0
    prefill_s: float = 0.0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def tokens(self) -> int:
        """Token-budget footprint: full prompt + full generation."""
        return self.prompt_len + self.max_new


class Scheduler:
    """FIFO queue + token-budget admission policy."""

    def __init__(self, max_batch_tokens: int = 0):
        self.max_batch_tokens = int(max_batch_tokens)   # 0 = unbounded
        self.queue: deque[Request] = deque()
        self.inflight: dict[int, Request] = {}
        self.inflight_tokens = 0
        # invariant counters (tests / telemetry)
        self.submitted_order: list[int] = []
        self.admitted_order: list[int] = []
        self.peak_inflight_tokens = 0
        self.slot_history: dict[int, list[int]] = {}

    def submit(self, req: Request) -> None:
        self.submitted_order.append(req.rid)
        self.queue.append(req)

    def try_admit(self, *, can_place) -> Request | None:
        """Admit the queue head iff the engine can place it (free slot +
        blocks, ``can_place(req)``) and it fits the token budget.
        Returns the admitted request or None."""
        if not self.queue:
            return None
        req = self.queue[0]
        if (self.max_batch_tokens
                and self.inflight_tokens + req.tokens > self.max_batch_tokens
                and self.inflight):      # never wedge an oversized head
            return None
        if not can_place(req):
            return None
        self.queue.popleft()
        self.inflight[req.rid] = req
        self.inflight_tokens += req.tokens
        self.admitted_order.append(req.rid)
        self.peak_inflight_tokens = max(self.peak_inflight_tokens,
                                        self.inflight_tokens)
        return req

    def record_slot(self, rid: int, slot: int) -> None:
        self.slot_history.setdefault(slot, []).append(rid)

    def release(self, req: Request) -> None:
        self.inflight.pop(req.rid)
        self.inflight_tokens -= req.tokens

    @property
    def pending(self) -> int:
        return len(self.queue)
