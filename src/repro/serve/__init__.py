"""Production serving plane: paged KV cache, jitted chunked prefill,
continuous batching (see README "Serving engine")."""
from repro.serve.engine import LoopEngine, PagedEngine, latency_percentiles
from repro.serve.kv_pool import KVPool
from repro.serve.scheduler import Request, Scheduler

__all__ = ["KVPool", "LoopEngine", "PagedEngine", "Request", "Scheduler",
           "latency_percentiles"]
