"""Paged KV block pool: one block-granular cache shared by all
in-flight requests.

Device side, the pool is the model's ``init_paged_pool`` tree — per
layer group, leaves (n_layers, num_blocks, block_size, KH, hd) plus a
``pos`` leaf (n_layers, num_blocks, block_size). Host side, this class
owns the free list. Block id 0 is RESERVED as the null/trash block:
block-table entry 0 means "unmapped" (gathered as pos=-1, i.e. fully
masked), and inactive decode slots write their dead tokens into it.

Freeing a request's blocks resets their ``pos`` entries to -1 so a
reader can never see a stale position through a recycled block before
its first write (slot reuse is gated in tests/test_serve_plane.py).
"""
from __future__ import annotations

import jax.numpy as jnp


class KVPool:
    def __init__(self, model, num_blocks: int, block_size: int):
        if model.init_paged_pool is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged-KV surface")
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.pool = model.init_paged_pool(num_blocks, block_size)
        # LIFO free list — finished requests' blocks are reused first,
        # which is exactly what the slot-reuse test asserts
        self._free = list(range(1, num_blocks))

    # ------------------------------------------------------ host side --
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    def blocks_for(self, ring_len: int) -> int:
        return -(-int(ring_len) // self.block_size)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(f"pool exhausted: want {n} blocks, "
                               f"{len(self._free)} free")
        blocks, self._free = self._free[-n:], self._free[:-n]
        return blocks

    def free(self, blocks: list[int]) -> None:
        if not blocks:
            return
        assert 0 not in blocks, "block 0 is reserved"
        idx = jnp.asarray(sorted(blocks), jnp.int32)
        self.pool = {
            g: (None if grp is None else
                dict(grp, pos=grp["pos"].at[:, idx].set(-1)))
            for g, grp in self.pool.items()}
        self._free.extend(sorted(blocks))
