"""Asynchronous AMA (paper §IV-B, Eqs. 6-11).

Delayed updates from round n arriving at round t enter the aggregation with
a staleness weight

    gamma_i^- = b * (1 - sigmoid(t - n))          (Eq. 9)
    alpha^-   = 1 - sigmoid(1)

normalised so the "old knowledge" budget alpha + sum(gamma_i) equals the AMA
schedule alpha0 + eta*t (Eq. 8) and alpha + beta + sum(gamma) = 1 (Eq. 7):

    alpha   = alpha^- / (alpha^- + sum_i gamma_i^-) * (alpha0 + eta t)
    gamma_i = gamma_i^- / (alpha^- + sum_i gamma_i^-) * (alpha0 + eta t)

Server-side state is a RING BUFFER over arrival rounds: an update sent at
round n with delay d arrives at n+d; its staleness d is known at send time,
so the server accumulates gamma^-(d) * omega into slot (n+d) % Q together
with the scalar sum of gamma^-. At round t the slot t % Q holds exactly
sum_i gamma_i^- omega_ni and sum_i gamma_i^- — O(max_delay) parameter
buffers regardless of client count, which is what makes the paper's scheme
feasible when omega is billions of parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.ama import (alpha_schedule, normalize_weights,
                            weighted_client_sum)

ALPHA_UNNORM = 1.0 - jax.nn.sigmoid(1.0)        # paper Eq. 9


def gamma_unnorm(fl: FLConfig, staleness):
    """gamma_i^- = b * (1 - sigmoid(staleness)); staleness = t - n >= 1.

    Computed as b * sigmoid(-s): algebraically identical, but avoids the
    catastrophic cancellation of 1 - sigmoid(s) for stale updates (f32
    1-sigmoid(15) loses all significant digits)."""
    s = jnp.asarray(staleness, jnp.float32)
    return fl.staleness_b * jax.nn.sigmoid(-s)


def init_queue(fl: FLConfig, params_like):
    """Ring buffer of gamma^- pre-weighted pending sums.

    Q = max_delay + 1 slots so an update with the maximum delay, enqueued
    at round t, never collides with the slot being drained at round t.
    """
    Q = max(fl.max_delay, 1) + 1
    zeros = jax.tree.map(
        lambda x: jnp.zeros((Q,) + x.shape, jnp.float32), params_like)
    return {"sum": zeros, "gamma": jnp.zeros((Q,), jnp.float32)}


def enqueue(fl: FLConfig, queue, t, client_params, delayed, delays):
    """Accumulate this round's DELAYED updates into their arrival slots.

    client_params: leading client axis (C, ...); delayed: (C,) bool;
    delays: (C,) int32 in [1, max_delay].
    """
    Q = queue["gamma"].shape[0]
    C = delays.shape[0]
    arrival = (jnp.asarray(t, jnp.int32) + delays) % Q          # (C,)
    g = gamma_unnorm(fl, delays) * delayed.astype(jnp.float32)  # (C,)
    onehot = jax.nn.one_hot(arrival, Q, dtype=jnp.float32) * g[:, None]

    def acc(buf, cp):
        add = jnp.einsum("c...,cq->q...", cp.astype(jnp.float32), onehot)
        return buf + add

    new_sum = jax.tree.map(acc, queue["sum"], client_params)
    new_gamma = queue["gamma"] + jnp.sum(onehot, axis=0)
    return {"sum": new_sum, "gamma": new_gamma}


def pop_slot(queue, t):
    """Read and clear the slot arriving at round t."""
    Q = queue["gamma"].shape[0]
    slot = jnp.asarray(t, jnp.int32) % Q
    stale_sum = jax.tree.map(lambda b: b[slot], queue["sum"])
    stale_gamma = queue["gamma"][slot]
    cleared = {
        "sum": jax.tree.map(lambda b: b.at[slot].set(0.0), queue["sum"]),
        "gamma": queue["gamma"].at[slot].set(0.0),
    }
    return stale_sum, stale_gamma, cleared


def async_ama_aggregate(fl: FLConfig, t, prev_global, client_params,
                        data_sizes, on_time, queue, *,
                        use_kernel: bool = False):
    """One asynchronous AMA round (Eq. 6). Returns (new_global, new_queue).

    client_params are THIS round's local results; clients with
    on_time=False contribute nothing now (their updates were enqueued by
    the caller via ``enqueue`` and will arrive later).
    """
    stale_sum, stale_gamma, queue = pop_slot(queue, t)

    A = alpha_schedule(fl, t)                       # alpha0 + eta t (Eq. 8)
    beta = 1.0 - A
    denom = ALPHA_UNNORM + stale_gamma
    alpha = ALPHA_UNNORM / denom * A                # Eq. 10
    gamma_scale = A / denom                         # Eq. 11 (applied to sum)

    w, tot = normalize_weights(data_sizes, on_time)
    agg = weighted_client_sum(client_params, w)
    agg = jax.tree.map(lambda a, p: jnp.where(tot > 0, a, p), agg, prev_global)
    # when no on-time arrivals, beta's budget reverts to the previous model
    # via the agg fallback above, preserving alpha+beta+gamma = 1.

    if use_kernel:
        # alpha*prev + beta*agg + gamma*stale is one fused K=2 mix.
        # The jnp.stack stages an extra (2, N) f32 copy to fit the
        # kernel's stacked-operand layout; a separate-ref kernel variant
        # would avoid it (acceptable while use_kernel is opt-in).
        from repro.kernels.ops import ama_mix_tree
        stacked = jax.tree.map(
            lambda a, s: jnp.stack([a.astype(jnp.float32), s]),
            agg, stale_sum)
        weights = jnp.stack([beta, gamma_scale])
        new_global = ama_mix_tree(prev_global, stacked, alpha, weights)
        return new_global, queue

    def mix(p, a, s):
        out = (alpha * p.astype(jnp.float32) + beta * a.astype(jnp.float32)
               + gamma_scale * s)
        return out.astype(p.dtype)

    new_global = jax.tree.map(mix, prev_global, agg, stale_sum)
    return new_global, queue


def mixing_weights(fl: FLConfig, t, staleness_list):
    """Reference computation of (alpha, beta, gammas) for a set of stale
    updates — used by tests/benchmarks to check Eqs. 7-11 analytically."""
    A = float(min(fl.alpha0 + fl.eta * t, fl.alpha_cap))
    g_un = [float(gamma_unnorm(fl, s)) for s in staleness_list]
    denom = float(ALPHA_UNNORM) + sum(g_un)
    alpha = float(ALPHA_UNNORM) / denom * A
    gammas = [g / denom * A for g in g_un]
    beta = 1.0 - A
    return alpha, beta, gammas
