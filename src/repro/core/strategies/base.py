"""The ServerStrategy interface and the name-keyed strategy registry.

The paper's contribution is the server aggregation rule; everything else
(local SGD, the scheduler, the scan engine) is shared machinery. A
``ServerStrategy`` packages the three places an aggregation rule can
differ:

  * ``init_state(params)`` — strategy-owned auxiliary server state
    (e.g. the async-AMA ring buffer, fedopt's Adam moments), carried
    through the round loop as a pytree;
  * ``local_grad_transform`` / ``local_steps`` — client-side hooks
    (FedProx's proximal pull + partial work, the FES gradient mask);
  * ``aggregate(t, prev_global, client_params, sched, aux_state)`` —
    the server update itself, a pure jittable function of the round
    index, the previous global model, the stacked client results and the
    round's schedule arrays;
  * ``fused_server_update(...)`` — the same update through the fused
    server-plane kernel suite (``repro.kernels.server_plane``): ONE
    Pallas pass per round (weights, delta accumulation, ring-buffer
    mix, server-Adam all in-kernel) instead of a chain of jnp ops. The
    round engine (``core.round.make_round_step``) dispatches here;
    ``fl.server_plane`` selects "fused" (pallas_call on TPU, the jitted
    flat oracle off-TPU), "ref" (always the oracle), "interpret" (the
    Pallas body through the interpreter — validation only) or "legacy"
    (the original per-leaf ``aggregate`` chain).

Every method is traced inside the jitted round (and inside the fused
``lax.scan`` over rounds), so implementations must be functional: no
Python-level branching on traced values, aux state in/out rather than
mutated.

Adding a new rule is one file: subclass ``ServerStrategy``, decorate it
with ``@register``, and it becomes reachable from every entry point
(``FederatedSimulation``, the pod round, ``--algorithm`` on the
launcher) with no dispatch chain to edit.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import FLConfig


class ServerStrategy:
    """Base class: FedAvg-shaped defaults, stateless, no grad transform."""

    #: registry key; aliases are extra names resolving to the same class
    name: str = ""
    aliases: tuple[str, ...] = ()
    #: True when aux_state is non-empty (changes the flat lowering signature)
    stateful: bool = False

    def __init__(self, fl: FLConfig):
        self.fl = fl

    # ---------------------------------------------------- server side ----
    def init_state(self, params):
        """Strategy-owned auxiliary server state (a pytree; {} if none)."""
        del params
        return {}

    def aggregate(self, t, prev_global, client_params, sched, aux_state):
        """One server update. ``client_params`` has a leading client axis;
        ``sched`` is {"limited","delayed","delays","data_sizes"}, each (C,).
        Returns (new_global, new_aux_state)."""
        raise NotImplementedError

    def fused_server_update(self, t, prev_global, client_params, sched,
                            aux_state):
        """One server update through the fused server-plane kernel suite
        (one HBM pass per round; see ``repro.kernels.server_plane``).
        Same signature and contract as ``aggregate``. The base fallback
        routes to ``aggregate`` so out-of-tree strategies keep working;
        built-ins override it and honour ``fl.server_plane``
        ("fused" | "ref" | "legacy")."""
        return self.aggregate(t, prev_global, client_params, sched,
                              aux_state)

    def compressed_server_update(self, t, prev_global, groups, sched,
                                 aux_state):
        """The server update consuming a comm plane's COMPRESSED payload
        directly — fused dequantize-accumulate, no dense (C, N) f32
        intermediate.

        ``groups`` is ``repro.comm``'s flat per-dtype-group payload list
        (``[(leaf_idxs, payload)]``, see
        ``kernels.server_plane.server_mix_compressed_tree``). The mix
        family overrides this; strategies whose update is not linear in
        the stacked deltas (async ring buffer, server-Adam) return
        ``NotImplemented`` (the base default) and the round engine
        densifies via ``CommPlane.reconstruct`` before their fused
        update — same numbers, one extra dense pass."""
        del t, prev_global, groups, sched, aux_state
        return NotImplemented

    def reduced_server_update(self, t, prev_global, client_params, sched,
                              aux_state):
        """The server update with the stacked client axis PRE-REDUCED.

        Every built-in server plane consumes ``client_params`` only
        through weighted sums over the client axis, so on a mesh whose
        "client" axis is sharded the engine can contract (C, N) -> (N,)
        (``sharding.ctx.reduce_leading``) BEFORE the server math — the
        per-round cross-device collective then moves N, not C x N,
        bytes. Same signature/contract as ``aggregate``; numerically
        allclose to (not bit-identical with) the fused plane's
        sequential multiply-add chains, which is why the round engine
        only dispatches here when ``fl.client_reduce`` asks for it
        ("auto" = the active mesh's client axis is > 1). Return
        ``NotImplemented`` (the base default) to always use the fused
        plane."""
        del t, prev_global, client_params, sched, aux_state
        return NotImplemented

    @property
    def server_impl(self) -> str:
        """The configured server-plane implementation."""
        return getattr(self.fl, "server_plane", "fused")

    # ---------------------------------------------------- telemetry ----
    def mix_coefficient(self, t, sched, aux_state):
        """The EFFECTIVE previous-model mix coefficient alpha of this
        round's server update — the telemetry plane's ``alpha_eff``
        series (``repro.obs.metrics.round_metrics``). Pure, traced
        inside the round (and the fused scan), must not touch the
        update itself. Pure weighted-average rules (fedavg/fedprox)
        keep the base 0; the AMA family reports the realized Eq. 5 /
        Eq. 10 schedule."""
        del t, sched, aux_state
        return jnp.float32(0.0)

    # ---------------------------------------------------- client side ----
    def local_grad_transform(self, grads, params, global_params, fes_mask,
                             limited):
        """Per-step gradient hook inside local training (identity here)."""
        del params, global_params, fes_mask, limited
        return grads

    def local_steps(self, n_steps: int, limited):
        """Number of active local steps for a client; ``n_steps`` is the
        static step count, ``limited`` the (traced) FES flag."""
        del limited
        return jnp.int32(n_steps)

    # -------------------------------------- partitioned client plane ----
    @property
    def limited_mode(self) -> str:
        """How a computing-limited cohort executes under the PARTITIONED
        client plane (``fl.client_plane = "partitioned"``):

          * ``"full"`` — the same gradients an unlimited cohort takes
            (the base default: ``local_grad_transform`` applies no FES
            mask, so the masked plane trains limited cohorts fully too);
          * ``"classifier"`` — classifier-only differentiation: the body
            backward is never traced (AMA-FES, paper Eq. 3).
        """
        return "full"

    def static_local_steps(self, n_steps: int) -> int:
        """Python-int local-step budget of a LIMITED cohort — the static
        scan length of the partitioned plane's limited program. Must
        agree with ``local_steps(n_steps, limited=True)`` (the masked
        plane's traced cutoff) for the two planes to be equivalent."""
        return n_steps


def reduced_mix_update(prev_global, client_params, sched, keep, alpha):
    """The mix-family server plane (``kernels.ref.server_mix_math``)
    with the client axis pre-reduced: out = a_eff*prev + sum_k
    (beta*w_k)*x_k, where the weighted sum is ONE ``reduce_leading``
    contraction (an N-byte collective on a sharded mesh). Shared by
    ama/fedavg/fedprox, which differ only in ``keep`` and the alpha
    schedule."""
    import jax

    from repro.kernels.ref import _norm_weights
    from repro.sharding.ctx import reduce_leading
    beta = 1.0 - alpha
    w, tot = _norm_weights(sched["data_sizes"], keep)
    a_eff = jnp.where(tot > 0, alpha, alpha + beta)
    red = reduce_leading(client_params, beta * w)
    return jax.tree.map(
        lambda p, r: (p.astype(jnp.float32) * a_eff + r).astype(p.dtype),
        prev_global, red)


_REGISTRY: dict[str, type[ServerStrategy]] = {}


def register(cls: type[ServerStrategy]) -> type[ServerStrategy]:
    """Class decorator: file-local registration under name + aliases."""
    assert cls.name, cls
    for key in (cls.name,) + tuple(cls.aliases):
        assert key not in _REGISTRY or _REGISTRY[key] is cls, key
        _REGISTRY[key] = cls
    return cls


def names() -> list[str]:
    """All registered strategy names (aliases included), sorted."""
    return sorted(_REGISTRY)


def get(name: str) -> type[ServerStrategy]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"registered: {names()}") from None


def resolve(fl: FLConfig) -> ServerStrategy:
    """Instantiate the strategy for a config. The AMA family upgrades to
    the asynchronous variant when the environment has delays
    (``max_delay > 0``), preserving the seed's behaviour where
    ``algorithm="ama_fes", max_delay=5`` meant async AMA."""
    cls = get(fl.algorithm)
    if fl.max_delay > 0 and cls.name == "ama":
        cls = get("async_ama")
    return cls(fl)
