"""Naive FL baseline (the paper's "FedAvg"): weighted average of the
clients that both finished (not computing-limited) and arrived on time;
no mixing with the previous model, no staleness handling."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.ama import fedavg_aggregate
from repro.core.strategies.base import (ServerStrategy,
                                        reduced_mix_update, register)


@register
class FedAvgStrategy(ServerStrategy):
    name = "fedavg"

    def aggregate(self, t, prev_global, client_params, sched, aux_state):
        del t
        on_time = jnp.logical_not(sched["delayed"])
        keep = jnp.logical_and(on_time, jnp.logical_not(sched["limited"]))
        new_global = fedavg_aggregate(prev_global, client_params,
                                      sched["data_sizes"], keep,
                                      use_kernel=self.fl.use_kernel)
        return new_global, aux_state

    def fused_server_update(self, t, prev_global, client_params, sched,
                            aux_state):
        if self.server_impl == "legacy":
            return self.aggregate(t, prev_global, client_params, sched,
                                  aux_state)
        from repro.kernels.server_plane import mix_coefs, server_mix_tree
        keep = jnp.logical_and(
            jnp.logical_not(sched["delayed"]),
            jnp.logical_not(sched["limited"])).astype(jnp.float32)
        # adaptive=False zeroes the alpha schedule: the plain weighted
        # average is the alpha=0 corner of the same fused pass
        new_global = server_mix_tree(
            prev_global, client_params, sched["data_sizes"], keep,
            mix_coefs(self.fl, t, adaptive=False), impl=self.server_impl)
        return new_global, aux_state

    def compressed_server_update(self, t, prev_global, groups, sched,
                                 aux_state):
        """The alpha=0 corner of the compressed mix: keep drops limited
        AND delayed clients, schedule zeroed."""
        if self.server_impl == "legacy":
            return NotImplemented
        from repro.kernels.server_plane import (mix_coefs,
                                                server_mix_compressed_tree)
        keep = jnp.logical_and(
            jnp.logical_not(sched["delayed"]),
            jnp.logical_not(sched["limited"])).astype(jnp.float32)
        new_global = server_mix_compressed_tree(
            prev_global, groups, sched["data_sizes"], keep,
            mix_coefs(self.fl, t, adaptive=False), impl=self.server_impl)
        return new_global, aux_state

    def reduced_server_update(self, t, prev_global, client_params, sched,
                              aux_state):
        del t
        keep = jnp.logical_and(
            jnp.logical_not(sched["delayed"]),
            jnp.logical_not(sched["limited"])).astype(jnp.float32)
        # alpha = 0: the plain weighted average corner of the mix plane
        return reduced_mix_update(prev_global, client_params, sched, keep,
                                  jnp.float32(0.0)), aux_state
