"""Asynchronous AMA (paper Eqs. 6-11) as a ServerStrategy.

The O(max_delay) ring buffer of gamma^- pre-weighted pending updates is
strategy-owned aux state: it rides the round-loop carry (including
through the fused ``lax.scan`` engine) instead of living as a special
"queue" field the round loop has to know about.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import async_ama
from repro.core.strategies.ama import AMAStrategy
from repro.core.strategies.base import register


@register
class AsyncAMAStrategy(AMAStrategy):
    name = "async_ama"
    aliases = ()
    stateful = True

    def init_state(self, params):
        return {"queue": async_ama.init_queue(self.fl, params)}

    def mix_coefficient(self, t, sched, aux_state):
        """The REALIZED Eq. 10 alpha of this round: the Eq. 8 budget
        A = alpha0 + eta*t renormalized by the staleness mass actually
        arriving now — the popped slot's gamma^- after this round's
        enqueue (the same order the update applies them). A pure
        scalar replay of the ring-buffer bookkeeping; the buffer
        itself is untouched."""
        fl = self.fl
        Q = aux_state["queue"]["gamma"].shape[0]
        delays = sched["delays"]
        arrival = (jnp.asarray(t, jnp.int32) + delays) % Q
        g = (async_ama.gamma_unnorm(fl, delays)
             * sched["delayed"].astype(jnp.float32))
        onehot = jax.nn.one_hot(arrival, Q, dtype=jnp.float32) * g[:, None]
        qgamma = aux_state["queue"]["gamma"] + jnp.sum(onehot, axis=0)
        stale_gamma = qgamma[jnp.asarray(t, jnp.int32) % Q]
        A = jnp.minimum(fl.alpha0 + fl.eta * jnp.asarray(t, jnp.float32),
                        fl.alpha_cap)
        return async_ama.ALPHA_UNNORM / (async_ama.ALPHA_UNNORM
                                         + stale_gamma) * A

    def aggregate(self, t, prev_global, client_params, sched, aux_state):
        on_time = jnp.logical_not(sched["delayed"])
        queue = async_ama.enqueue(self.fl, aux_state["queue"], t,
                                  client_params, sched["delayed"],
                                  sched["delays"])
        new_global, queue = async_ama.async_ama_aggregate(
            self.fl, t, prev_global, client_params, sched["data_sizes"],
            on_time, queue, use_kernel=self.fl.use_kernel)
        return new_global, {"queue": queue}

    def compressed_server_update(self, t, prev_global, groups, sched,
                                 aux_state):
        """The ring-buffer enqueue needs the DENSE delayed updates (they
        persist across rounds at full precision), so the AMA-family
        compressed hook this class inherits does not apply — revert to
        NotImplemented and let the round engine densify the payload
        before ``fused_server_update``."""
        del t, prev_global, groups, sched, aux_state
        return NotImplemented

    def fused_server_update(self, t, prev_global, client_params, sched,
                            aux_state):
        if self.server_impl == "legacy":
            return self.aggregate(t, prev_global, client_params, sched,
                                  aux_state)
        from repro.kernels.server_plane import server_async_tree
        fl = self.fl
        hyp = jnp.asarray([fl.alpha0, fl.eta, fl.alpha_cap,
                           fl.staleness_b], jnp.float32)
        new_global, queue = server_async_tree(
            prev_global, client_params, aux_state["queue"],
            sched["data_sizes"], sched["delayed"].astype(jnp.float32),
            sched["delays"], t, hyp, impl=self.server_impl)
        return new_global, {"queue": queue}

    def reduced_server_update(self, t, prev_global, client_params, sched,
                              aux_state):
        """``kernels.ref.server_async_math`` with the client axis
        pre-reduced: the on-time aggregate AND the Q ring-buffer enqueue
        sums are ONE (C, 1+Q) ``reduce_leading`` contraction, so the
        per-round collective moves (1+Q) x N bytes instead of C x N."""
        from repro.kernels.ref import _norm_weights
        from repro.sharding.ctx import reduce_leading
        fl = self.fl
        queue = aux_state["queue"]
        Q = queue["gamma"].shape[0]
        tt = jnp.asarray(t, jnp.int32)
        delayed = sched["delayed"].astype(jnp.float32)
        delays = sched["delays"]

        alpha_un = 1.0 - jax.nn.sigmoid(1.0)                    # Eq. 9
        g = (fl.staleness_b * jax.nn.sigmoid(-delays.astype(jnp.float32))
             * delayed)                                         # gamma^-
        arrival = (tt + delays) % Q
        onehot = (arrival[:, None] == jnp.arange(Q)[None, :]
                  ).astype(jnp.float32) * g[:, None]            # (C, Q)
        qg = queue["gamma"] + jnp.sum(onehot, axis=0)
        sel = (jnp.arange(Q) == tt % Q).astype(jnp.float32)     # pop mask
        stale_gamma = jnp.sum(qg * sel)
        new_qgamma = qg * (1.0 - sel)

        A = jnp.minimum(fl.alpha0 + fl.eta * tt.astype(jnp.float32),
                        fl.alpha_cap)
        beta = 1.0 - A
        denom = alpha_un + stale_gamma
        alpha = alpha_un / denom * A                            # Eq. 10
        gscale = A / denom                                      # Eq. 11
        w, tot = _norm_weights(sched["data_sizes"], 1.0 - delayed)
        a_eff = jnp.where(tot > 0, alpha, alpha + beta)

        # col 0: beta-weighted on-time aggregate; cols 1..Q: enqueue
        W = jnp.concatenate([(beta * w)[:, None], onehot], axis=1)
        red = reduce_leading(client_params, W)        # leaves (1+Q, ...)
        rows = jax.tree.map(lambda qs, r: qs + r[1:], queue["sum"], red)

        def selb(x):
            return sel.reshape((Q,) + (1,) * (x.ndim - 1))

        new_params = jax.tree.map(
            lambda p, r, rw: (p.astype(jnp.float32) * a_eff + r[0]
                              + jnp.sum(rw * selb(rw), axis=0) * gscale
                              ).astype(p.dtype),
            prev_global, red, rows)
        new_qsum = jax.tree.map(lambda rw: rw * (1.0 - selb(rw)), rows)
        return new_params, {"queue": {"sum": new_qsum,
                                      "gamma": new_qgamma}}
