"""Asynchronous AMA (paper Eqs. 6-11) as a ServerStrategy.

The O(max_delay) ring buffer of gamma^- pre-weighted pending updates is
strategy-owned aux state: it rides the round-loop carry (including
through the fused ``lax.scan`` engine) instead of living as a special
"queue" field the round loop has to know about.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import async_ama
from repro.core.strategies.ama import AMAStrategy
from repro.core.strategies.base import register


@register
class AsyncAMAStrategy(AMAStrategy):
    name = "async_ama"
    aliases = ()
    stateful = True

    def init_state(self, params):
        return {"queue": async_ama.init_queue(self.fl, params)}

    def aggregate(self, t, prev_global, client_params, sched, aux_state):
        on_time = jnp.logical_not(sched["delayed"])
        queue = async_ama.enqueue(self.fl, aux_state["queue"], t,
                                  client_params, sched["delayed"],
                                  sched["delays"])
        new_global, queue = async_ama.async_ama_aggregate(
            self.fl, t, prev_global, client_params, sched["data_sizes"],
            on_time, queue, use_kernel=self.fl.use_kernel)
        return new_global, {"queue": queue}

    def fused_server_update(self, t, prev_global, client_params, sched,
                            aux_state):
        if self.server_impl == "legacy":
            return self.aggregate(t, prev_global, client_params, sched,
                                  aux_state)
        from repro.kernels.server_plane import server_async_tree
        fl = self.fl
        hyp = jnp.asarray([fl.alpha0, fl.eta, fl.alpha_cap,
                           fl.staleness_b], jnp.float32)
        new_global, queue = server_async_tree(
            prev_global, client_params, aux_state["queue"],
            sched["data_sizes"], sched["delayed"].astype(jnp.float32),
            sched["delays"], t, hyp, impl=self.server_impl)
        return new_global, {"queue": queue}
