"""FedProx baseline (paper Eq. 4): proximal gradient pull toward the
global model plus "partial work" — computing-limited devices run a
fraction of the local steps instead of masking gradients."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ama import fedavg_aggregate
from repro.core.strategies.base import (ServerStrategy,
                                        reduced_mix_update, register)


@register
class FedProxStrategy(ServerStrategy):
    name = "fedprox"

    def local_grad_transform(self, grads, params, global_params, fes_mask,
                             limited):
        del fes_mask, limited
        rho = self.fl.fedprox_rho
        return jax.tree.map(
            lambda gi, p, p0: gi + 2.0 * rho
            * (p.astype(jnp.float32)
               - p0.astype(jnp.float32)).astype(gi.dtype),
            grads, params, global_params)

    def local_steps(self, n_steps: int, limited):
        n_partial = self.static_local_steps(n_steps)
        return jnp.where(limited, jnp.int32(n_partial), jnp.int32(n_steps))

    def static_local_steps(self, n_steps: int) -> int:
        """Partial work: under the partitioned client plane a limited
        cohort's program scans only this many steps — the masked plane
        computes the full scan and discards the gradients instead."""
        return max(1, int(self.fl.fedprox_partial * n_steps))

    def aggregate(self, t, prev_global, client_params, sched, aux_state):
        del t
        on_time = jnp.logical_not(sched["delayed"])
        new_global = fedavg_aggregate(prev_global, client_params,
                                      sched["data_sizes"], on_time,
                                      use_kernel=self.fl.use_kernel)
        return new_global, aux_state

    def fused_server_update(self, t, prev_global, client_params, sched,
                            aux_state):
        if self.server_impl == "legacy":
            return self.aggregate(t, prev_global, client_params, sched,
                                  aux_state)
        from repro.kernels.server_plane import mix_coefs, server_mix_tree
        keep = jnp.logical_not(sched["delayed"]).astype(jnp.float32)
        new_global = server_mix_tree(
            prev_global, client_params, sched["data_sizes"], keep,
            mix_coefs(self.fl, t, adaptive=False), impl=self.server_impl)
        return new_global, aux_state

    def compressed_server_update(self, t, prev_global, groups, sched,
                                 aux_state):
        """On-time weighted average (alpha=0) over compressed deltas."""
        if self.server_impl == "legacy":
            return NotImplemented
        from repro.kernels.server_plane import (mix_coefs,
                                                server_mix_compressed_tree)
        keep = jnp.logical_not(sched["delayed"]).astype(jnp.float32)
        new_global = server_mix_compressed_tree(
            prev_global, groups, sched["data_sizes"], keep,
            mix_coefs(self.fl, t, adaptive=False), impl=self.server_impl)
        return new_global, aux_state

    def reduced_server_update(self, t, prev_global, client_params, sched,
                              aux_state):
        del t
        keep = jnp.logical_not(sched["delayed"]).astype(jnp.float32)
        return reduced_mix_update(prev_global, client_params, sched, keep,
                                  jnp.float32(0.0)), aux_state
