"""Synchronous AMA (paper Eq. 5) as a ServerStrategy.

Client side this is the paper's AMA-FES pairing: when FES is enabled the
gradient of computing-limited devices is masked to the classifier split
(Eq. 2) via ``masked_update``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.ama import ama_aggregate
from repro.core.strategies.base import (ServerStrategy, reduced_mix_update,
                                        register)
from repro.optim.masked import masked_update


@register
class AMAStrategy(ServerStrategy):
    name = "ama"
    aliases = ("ama_fes",)   # seed config name; resolve() picks async when
                             # the environment has delays (max_delay > 0)

    def local_grad_transform(self, grads, params, global_params, fes_mask,
                             limited):
        del params, global_params
        if self.fl.fes_enabled:
            return masked_update(grads, fes_mask, limited)
        return grads

    @property
    def limited_mode(self) -> str:
        """Partitioned plane: limited cohorts differentiate only the
        classifier (Eq. 3) when FES is on — the executed counterpart of
        the masked plane's zeroed body gradients."""
        return "classifier" if self.fl.fes_enabled else "full"

    def mix_coefficient(self, t, sched, aux_state):
        """Eq. 5: alpha_t = min(alpha0 + eta*t, cap) — the adaptive
        schedule the fused mix applies this round."""
        del sched, aux_state
        fl = self.fl
        return jnp.minimum(fl.alpha0 + fl.eta
                           * jnp.asarray(t, jnp.float32), fl.alpha_cap)

    def aggregate(self, t, prev_global, client_params, sched, aux_state):
        on_time = jnp.logical_not(sched["delayed"])
        new_global = ama_aggregate(
            self.fl, t, prev_global, client_params, sched["data_sizes"],
            on_time, use_kernel=self.fl.use_kernel)
        return new_global, aux_state

    def fused_server_update(self, t, prev_global, client_params, sched,
                            aux_state):
        if self.server_impl == "legacy":
            return self.aggregate(t, prev_global, client_params, sched,
                                  aux_state)
        from repro.kernels.server_plane import mix_coefs, server_mix_tree
        keep = jnp.logical_not(sched["delayed"]).astype(jnp.float32)
        new_global = server_mix_tree(
            prev_global, client_params, sched["data_sizes"], keep,
            mix_coefs(self.fl, t), impl=self.server_impl)
        return new_global, aux_state

    def compressed_server_update(self, t, prev_global, groups, sched,
                                 aux_state):
        """Eq. 5 mix consuming compressed deltas in-kernel (q8/bf16 rows
        or top-k scatter); "legacy" has no compressed path — the engine
        densifies and falls back."""
        if self.server_impl == "legacy":
            return NotImplemented
        from repro.kernels.server_plane import (mix_coefs,
                                                server_mix_compressed_tree)
        keep = jnp.logical_not(sched["delayed"]).astype(jnp.float32)
        new_global = server_mix_compressed_tree(
            prev_global, groups, sched["data_sizes"], keep,
            mix_coefs(self.fl, t), impl=self.server_impl)
        return new_global, aux_state

    def reduced_server_update(self, t, prev_global, client_params, sched,
                              aux_state):
        fl = self.fl
        alpha = jnp.minimum(fl.alpha0 + fl.eta
                            * jnp.asarray(t, jnp.float32), fl.alpha_cap)
        keep = jnp.logical_not(sched["delayed"]).astype(jnp.float32)
        return reduced_mix_update(prev_global, client_params, sched, keep,
                                  alpha), aux_state
