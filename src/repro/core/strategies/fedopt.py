"""FedOpt: server-side Adam on the aggregated pseudo-gradient (Reddi et
al. 2021's FedAdam, the new extension-point proof for this registry).

The on-time weighted average of client models defines a pseudo-gradient
Delta_t = agg_t - omega_{t-1}; the server applies one Adam step with its
own (lr, b1, b2, tau) instead of AMA's convex mix. Aux state is the
(m, v, step) moment pytree — the same carry mechanism that holds the
async ring buffer, which is exactly what makes this a one-file addition.

Client side it inherits AMA's FES masking, so fedopt composes with the
paper's computation-reduction scheme unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ama import normalize_weights, weighted_client_sum
from repro.core.strategies.ama import AMAStrategy
from repro.core.strategies.base import register


@register
class FedOptStrategy(AMAStrategy):
    name = "fedopt"
    aliases = ()
    stateful = True

    def init_state(self, params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": zeros(), "v": zeros(),
                "step": jnp.zeros((), jnp.int32)}

    def mix_coefficient(self, t, sched, aux_state):
        """FedOpt takes an Adam step on the pseudo-gradient rather than
        a convex mix, so the AMA alpha it inherits does not describe
        its update — report 0 like the other non-mix rules."""
        del t, sched, aux_state
        return jnp.float32(0.0)

    def aggregate(self, t, prev_global, client_params, sched, aux_state):
        del t  # fedopt keys its schedule on its own step counter
        fl = self.fl
        on_time = jnp.logical_not(sched["delayed"])
        w, tot = normalize_weights(sched["data_sizes"], on_time)
        agg = weighted_client_sum(client_params, w)
        agg = jax.tree.map(lambda a, p: jnp.where(tot > 0, a, p),
                           agg, prev_global)

        delta = jax.tree.map(
            lambda a, p: a.astype(jnp.float32) - p.astype(jnp.float32),
            agg, prev_global)
        step = aux_state["step"] + 1
        m = jax.tree.map(lambda mm, d: fl.server_b1 * mm
                         + (1.0 - fl.server_b1) * d, aux_state["m"], delta)
        v = jax.tree.map(lambda vv, d: fl.server_b2 * vv
                         + (1.0 - fl.server_b2) * d * d, aux_state["v"], delta)
        sf = step.astype(jnp.float32)
        bc1 = 1.0 - fl.server_b1 ** sf
        bc2 = 1.0 - fl.server_b2 ** sf
        update = jax.tree.map(
            lambda mm, vv: (mm / bc1)
            / (jnp.sqrt(vv / bc2) + fl.server_tau), m, v)

        if fl.use_kernel:
            # prev + lr*update == 1.0*prev + sum_k w_k stacked_k with
            # K=1, w=[lr]: the general fused-mix kernel, not a special case
            from repro.kernels.ops import ama_mix_tree
            stacked = jax.tree.map(lambda u: u[None], update)
            new_global = ama_mix_tree(prev_global, stacked, 1.0,
                                      jnp.full((1,), fl.server_lr))
        else:
            new_global = jax.tree.map(
                lambda p, u: (p.astype(jnp.float32)
                              + fl.server_lr * u).astype(p.dtype),
                prev_global, update)
        return new_global, {"m": m, "v": v, "step": step}

    def compressed_server_update(self, t, prev_global, groups, sched,
                                 aux_state):
        """Server-Adam is nonlinear in the aggregated pseudo-gradient
        (second moment, rsqrt), so the linear compressed mix this class
        inherits from AMA does not describe it — revert to
        NotImplemented; the round engine densifies the payload and
        dispatches the fused Adam plane."""
        del t, prev_global, groups, sched, aux_state
        return NotImplemented

    def fused_server_update(self, t, prev_global, client_params, sched,
                            aux_state):
        if self.server_impl == "legacy":
            return self.aggregate(t, prev_global, client_params, sched,
                                  aux_state)
        from repro.kernels.server_plane import server_adam_tree
        fl = self.fl
        keep = jnp.logical_not(sched["delayed"]).astype(jnp.float32)
        step = aux_state["step"] + 1
        scalars = jnp.stack([jnp.float32(fl.server_b1),
                             jnp.float32(fl.server_b2),
                             jnp.float32(fl.server_lr),
                             jnp.float32(fl.server_tau),
                             step.astype(jnp.float32)])
        new_global, m, v = server_adam_tree(
            prev_global, client_params, aux_state["m"], aux_state["v"],
            sched["data_sizes"], keep, scalars, impl=self.server_impl)
        return new_global, {"m": m, "v": v, "step": step}

    def reduced_server_update(self, t, prev_global, client_params, sched,
                              aux_state):
        """``kernels.ref.server_adam_math`` with the pseudo-gradient
        aggregate pre-reduced over the client axis (one N-byte
        contraction); the Adam moment update is elementwise on (N,)."""
        del t
        from repro.kernels.ref import _norm_weights
        from repro.sharding.ctx import reduce_leading
        fl = self.fl
        keep = jnp.logical_not(sched["delayed"]).astype(jnp.float32)
        w, tot = _norm_weights(sched["data_sizes"], keep)
        agg = reduce_leading(client_params, w)
        step = aux_state["step"] + 1
        sf = step.astype(jnp.float32)
        bc1 = 1.0 - fl.server_b1 ** sf
        bc2 = 1.0 - fl.server_b2 ** sf

        def delta(p, a):
            return jnp.where(tot > 0, a - p.astype(jnp.float32), 0.0)

        m = jax.tree.map(
            lambda mm, p, a: fl.server_b1 * mm
            + (1.0 - fl.server_b1) * delta(p, a),
            aux_state["m"], prev_global, agg)
        v = jax.tree.map(
            lambda vv, p, a: fl.server_b2 * vv
            + (1.0 - fl.server_b2) * delta(p, a) ** 2,
            aux_state["v"], prev_global, agg)
        new_params = jax.tree.map(
            lambda p, mm, vv: (p.astype(jnp.float32) + fl.server_lr
                               * (mm / bc1)
                               / (jnp.sqrt(vv / bc2) + fl.server_tau)
                               ).astype(p.dtype),
            prev_global, m, v)
        return new_params, {"m": m, "v": v, "step": step}
