"""Pluggable server-strategy subsystem — the one home for algorithm
dispatch. Importing this package registers the built-in strategies:

    ama (alias ama_fes) | async_ama | fedavg | fedprox | fedopt

Use ``resolve(fl)`` to get the strategy instance for a config, or
``get(name)`` / ``names()`` to address the registry directly.
"""
from repro.core.strategies.base import (ServerStrategy, get, names, register,
                                        resolve)
from repro.core.strategies.ama import AMAStrategy
from repro.core.strategies.async_ama import AsyncAMAStrategy
from repro.core.strategies.fedavg import FedAvgStrategy
from repro.core.strategies.fedopt import FedOptStrategy
from repro.core.strategies.fedprox import FedProxStrategy

__all__ = ["ServerStrategy", "register", "resolve", "get", "names",
           "AMAStrategy", "AsyncAMAStrategy", "FedAvgStrategy",
           "FedOptStrategy", "FedProxStrategy"]
