from repro.core.ama import ama_aggregate, ama_mix, alpha_schedule, fedavg_aggregate
from repro.core.async_ama import async_ama_aggregate, init_queue, enqueue, mixing_weights
from repro.core.client import make_local_train, make_fes_local_train
from repro.core.round import (make_round_step, make_train_loop,
                              make_train_step_for_lowering, init_state)
from repro.core import strategies


def __getattr__(name):
    # lazy back-compat re-export: simulation imports repro.exec.engine,
    # which imports repro.core — importing it eagerly here makes package
    # init order decide whether `import repro.exec.engine` works at all
    if name in ("FederatedSimulation", "History"):
        from repro.core import simulation
        return getattr(simulation, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
