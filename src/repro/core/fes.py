"""Feature-Extractor Sharing (paper §III, Eqs. 2-3).

Computing-limited clients freeze the feature extractor omega^f and train
only the classifier omega^c. Two execution modes:

* ``split_params`` / ``merge_params`` — STATIC mode: differentiate only the
  classifier subtree. The frozen body's backward pass is never built, so the
  computation reduction is real (visible as reduced HLO FLOPs in the
  dry-run), exactly the paper's point about CPU-friendliness.
* ``masked_update`` (optim.masked) — DYNAMIC mode: one compiled step serves
  cohorts whose limited-ness is a traced bool (mixed-cohort pod rounds).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.models.api import CLASSIFIER_KEYS


def split_params(params):
    """(classifier, feature_extractor) by the FES boundary."""
    clf = {k: v for k, v in params.items() if k in CLASSIFIER_KEYS}
    fes = {k: v for k, v in params.items() if k not in CLASSIFIER_KEYS}
    return clf, fes


def merge_params(clf, fes):
    return {**fes, **clf}


def fes_loss_fn(model):
    """loss(classifier_params, frozen_body) — grads flow only into the
    classifier; XLA never builds the body backward."""
    def loss(clf, fes, batch):
        return model.loss(merge_params(clf, jax.lax.stop_gradient(fes)), batch)
    return loss


def count_trainable(params, mask):
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    train = sum(
        int(np.prod(x.shape)) if m else 0
        for x, m in zip(jax.tree.leaves(params), jax.tree.leaves(mask)))
    return train, total
