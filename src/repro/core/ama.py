"""Adaptive Mixing Aggregation (paper §IV-A, Eq. 5).

    omega_t = alpha_t * omega_{t-1} + beta_t * sum_i w_i * omega_ti
    alpha_t = alpha0 + eta * t            beta_t = 1 - alpha_t

Interpretation note (recorded in EXPERIMENTS.md): the paper writes client
weights |d_i|/|D| with |D| the size of the FULL federated dataset; summed
over the m selected clients those weights do not reach 1, which would shrink
the model by alpha + beta * (m/K) each round. We follow the standard FedAvg
convention the results only make sense under: weights are normalised over
the *participating* (on-time) clients, w_i = |d_i| / sum_{j in k_t} |d_j|.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig


def alpha_schedule(fl: FLConfig, t):
    """alpha_t = alpha0 + eta*t, capped to keep beta > 0 on long runs."""
    return jnp.minimum(fl.alpha0 + fl.eta * jnp.asarray(t, jnp.float32),
                       fl.alpha_cap)


def weighted_client_sum(stacked, weights):
    """sum_c weights[c] * stacked[c]; stacked has leading client axis."""
    def red(x):
        w = weights.astype(jnp.float32)
        return jnp.einsum("c...,c->...", x.astype(jnp.float32), w).astype(x.dtype)
    return jax.tree.map(red, stacked)


def normalize_weights(data_sizes, on_time):
    """w_i = |d_i| / sum_on_time |d_j|; zero for delayed/absent clients."""
    w = data_sizes.astype(jnp.float32) * on_time.astype(jnp.float32)
    tot = jnp.sum(w)
    return w / jnp.maximum(tot, 1e-9), tot


def ama_mix(prev_global, client_agg, alpha, *, use_kernel: bool = False):
    """alpha * prev + (1 - alpha) * agg, leafwise.

    use_kernel routes through the fused Pallas kernel (TPU target); the
    default jnp path is what CPU tests and the dry-run lower.
    """
    if use_kernel:
        from repro.kernels.ops import ama_mix_pairwise
        return ama_mix_pairwise(prev_global, client_agg, alpha)
    a = jnp.asarray(alpha, jnp.float32)
    return jax.tree.map(
        lambda p, g: (a * p.astype(jnp.float32)
                      + (1.0 - a) * g.astype(jnp.float32)).astype(p.dtype),
        prev_global, client_agg)


def ama_aggregate(fl: FLConfig, t, prev_global, client_params, data_sizes,
                  on_time=None, *, use_kernel: bool = False):
    """Synchronous AMA round (Eq. 5). client_params: leading client axis."""
    C = jax.tree.leaves(client_params)[0].shape[0]
    if on_time is None:
        on_time = jnp.ones((C,), bool)
    w, tot = normalize_weights(data_sizes, on_time)
    agg = weighted_client_sum(client_params, w)
    # if nobody arrived on time, reallocate beta to the previous model
    agg = jax.tree.map(
        lambda a, p: jnp.where(tot > 0, a, p), agg, prev_global)
    alpha = alpha_schedule(fl, t)
    return ama_mix(prev_global, agg, alpha, use_kernel=use_kernel)


def fedavg_aggregate(prev_global, client_params, data_sizes, on_time=None,
                     *, use_kernel: bool = False):
    """Naive FL (paper's baseline): plain weighted average of on-time
    updates; falls back to the previous model if none arrived."""
    C = jax.tree.leaves(client_params)[0].shape[0]
    if on_time is None:
        on_time = jnp.ones((C,), bool)
    w, tot = normalize_weights(data_sizes, on_time)
    agg = weighted_client_sum(client_params, w)
    agg = jax.tree.map(lambda a, p: jnp.where(tot > 0, a, p), agg, prev_global)
    # a FedAvg round is the alpha=0 corner of the AMA mix: same fused
    # kernel path serves it when use_kernel is on
    return ama_mix(prev_global, agg, 0.0, use_kernel=use_kernel)
