"""Paper-scale federated simulation (K clients, m selected/round).

``FederatedSimulation`` is the paper-scale configuration of the unified
chunked-scan execution engine (``repro.exec``): the same fused
``make_train_loop`` round path, vectorized chunk staging, jitted batched
eval and FL-mesh sharding that drive the pod scale, here fed from K
simulated clients' non-iid shards with the full heterogeneous
environment of §V. Both halves are plugins: the server rule is a
ServerStrategy from ``repro.core.strategies`` and the world is an
Environment from ``repro.env`` (``fl.env``: bernoulli / gilbert_elliott
/ bandwidth / trace) — the simulation owns no algorithm or channel
logic, only data movement and evaluation.

Kept as an import point for backwards compatibility; the implementation
lives in ``repro.exec.engine``.
"""
from __future__ import annotations

from repro.exec.engine import History, SimulationEngine

__all__ = ["FederatedSimulation", "History"]


class FederatedSimulation(SimulationEngine):
    """The paper's §V experiment on the unified execution engine.

    ``run`` routes through the fused chunked scan by default
    (``use_scan=False`` for the bit-identical per-round fallback);
    ``save``/``resume`` checkpoint the whole round state.
    """
