"""Paper-scale federated simulation (K clients, m selected/round).

Drives the same jitted round engine as the pod path, but with the full
heterogeneous environment of §V: non-iid 2-class shards, a fixed
computing-limited subset (FES), and stochastic upload delays. Both
halves are plugins: the server rule is a ServerStrategy from
``repro.core.strategies`` and the world is an Environment from
``repro.env`` (``fl.env``: bernoulli / gilbert_elliott / bandwidth /
trace) — the simulation owns no algorithm or channel logic, only data
movement and evaluation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import env as env_mod
from repro.configs.base import FLConfig
from repro.core import strategies
from repro.core.client import make_local_train


@dataclass
class History:
    test_acc: list = field(default_factory=list)
    test_loss: list = field(default_factory=list)
    train_loss: list = field(default_factory=list)

    def stability_variance(self, last: int = 50) -> float:
        """Paper's stability metric: variance of test accuracy over the
        last ``last`` rounds (in percentage points squared)."""
        accs = np.array(self.test_acc[-last:]) * 100.0
        return float(np.var(accs))

    def final_accuracy(self, last: int = 50) -> float:
        return float(np.mean(self.test_acc[-last:]))


class FederatedSimulation:
    def __init__(self, model, fl: FLConfig, clients, test_data,
                 eval_fn=None, eval_batch: int = 512, environment=None):
        self.model = model
        self.fl = fl
        self.clients = clients
        self.test_data = test_data
        # any registered environment (fl.env); data sizes feed the
        # |D_i| aggregation weights through the schedule contract
        self.env = environment or env_mod.resolve(
            fl, data_sizes=np.array([len(c) for c in clients], np.float32))
        self.rng = np.random.RandomState(fl.seed + 7)
        self.strategy = strategies.resolve(fl)
        self._local_train = jax.jit(make_local_train(model, fl,
                                                     self.strategy))
        self._aggregate = jax.jit(self.strategy.aggregate)
        self._eval_fn = eval_fn
        self.eval_batch = eval_batch

        self.params = model.init(jax.random.PRNGKey(fl.seed))
        self.t = 0
        self.aux = self.strategy.init_state(self.params)

    # ------------------------------------------------------------------
    def _steps_per_round(self) -> int:
        n_min = min(len(c) for c in self.clients)
        per_epoch = max(1, n_min // self.fl.local_batch_size)
        return self.fl.local_epochs * per_epoch

    def run_round(self) -> float:
        fl = self.fl
        rs = self.env.round(self.t)
        steps = self._steps_per_round()
        batches = [self.clients[i].sample_steps(self.rng, steps,
                                                fl.local_batch_size)
                   for i in rs.selected]
        batches = {k: np.stack([b[k] for b in batches]) for k in batches[0]}
        sched = {
            "limited": jnp.asarray(rs.limited),
            "delayed": jnp.asarray(rs.delayed),
            "delays": jnp.asarray(rs.delays),
            "data_sizes": jnp.asarray(rs.data_sizes, jnp.float32),
        }

        client_params, losses = self._local_train(self.params, batches,
                                                  sched["limited"])
        self.params, self.aux = self._aggregate(
            jnp.int32(self.t), self.params, client_params, sched, self.aux)
        self.t += 1
        return float(jnp.mean(losses))

    # ------------------------------------------------------------------
    def evaluate(self):
        if self._eval_fn is None:
            from repro.models import cnn
            logits, _ = cnn.forward(self.params, self.model.cfg,
                                    self.test_data)
            labels = self.test_data["label"]
            acc = float(jnp.mean((jnp.argmax(logits, -1) == labels)))
            from repro.models.layers import cross_entropy_loss
            loss = float(cross_entropy_loss(logits, labels))
            return acc, loss
        return self._eval_fn(self.params, self.test_data)

    def run(self, rounds: int | None = None, eval_every: int = 1,
            verbose: bool = False) -> History:
        hist = History()
        rounds = rounds or self.fl.rounds
        for r in range(rounds):
            tl = self.run_round()
            hist.train_loss.append(tl)
            if (r + 1) % eval_every == 0:
                acc, loss = self.evaluate()
                hist.test_acc.append(acc)
                hist.test_loss.append(loss)
                if verbose and (r + 1) % 10 == 0:
                    print(f"  round {r+1:4d} train_loss={tl:.4f} "
                          f"test_acc={acc:.4f}")
        return hist
