"""Client-side local training (paper Alg. 1, lines 11-16).

One engine serves both scales:
  * paper scale — m=10 selected clients vmapped, e local epochs;
  * pod scale  — C cohorts, stacked params sharded over the "client" mesh
    axis; no cross-client collectives inside the local scan (this is the
    defining difference from data-parallel training).

Algorithm behaviour is injected through the ServerStrategy client hooks
(``local_grad_transform``, ``local_steps``, ``limited_mode``,
``static_local_steps``) — the AMA family masks FES gradients, FedProx
adds the proximal pull (Eq. 4) and runs partial work on limited devices;
this module contains no per-algorithm branching.

Three client-plane programs (``fl.client_plane`` / ``fl.fes_static``):
  * ``make_local_train`` — the MASKED plane: one program for every
    cohort, ``limited`` a traced per-cohort bool. Limited cohorts pay
    the full body backward and mask/freeze it — the bit-identity
    reference for mixed cohorts.
  * ``make_limited_local_train`` — the limited-group program of the
    PARTITIONED plane: classifier-only differentiation (the body
    backward is never traced — the paper's Eq. 3 computation reduction
    for real) or a statically truncated full-gradient scan (FedProx
    partial work), per the strategy's ``limited_mode``.
  * ``make_fes_local_train`` — STATIC mode: every cohort limited.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import fes as fes_lib
from repro.core import strategies


def _sgd(params, grads, lr: float):
    """The shared local SGD update (f32 accumulate, params' dtype out) —
    bit-identical to the masked plane's active branch."""
    return jax.tree.map(
        lambda p, gi: (p.astype(jnp.float32)
                       - lr * gi.astype(jnp.float32)).astype(p.dtype),
        params, grads)


def make_local_train(model, fl: FLConfig, strategy=None):
    """Returns local_train(global_params, batches, limited) ->
    (client_params (C, ...), mean_loss (C,)).

    batches: pytree with leading (C, steps, batch, ...) axes.
    limited: (C,) bool — FES-limited cohorts (dynamic mask mode).
    """
    strategy = strategy or strategies.resolve(fl)
    grad_fn = jax.value_and_grad(model.loss)

    def one_client(params0, global_params, batches, limited):
        mask = model.fes_mask(params0)
        n_steps = jax.tree.leaves(batches)[0].shape[0]
        n_active = strategy.local_steps(n_steps, limited)

        def step(carry, mb):
            params, i = carry
            loss, g = grad_fn(params, mb)
            g = strategy.local_grad_transform(g, params, global_params,
                                              mask, limited)
            active = i < n_active
            new_params = jax.tree.map(
                lambda p, gi: jnp.where(
                    active,
                    (p.astype(jnp.float32)
                     - fl.lr * gi.astype(jnp.float32)), p.astype(jnp.float32)
                ).astype(p.dtype),
                params, g)
            return (new_params, i + 1), loss

        (params, _), losses = jax.lax.scan(
            step, (params0, jnp.int32(0)), batches)
        # losses past the strategy's local_steps cutoff are computed at
        # FROZEN params (partial work keeps scanning but stops updating);
        # averaging them in would bias mean_loss toward the stale value,
        # so the mean covers active steps only
        active = jnp.arange(n_steps) < n_active
        mean_loss = (jnp.sum(losses * active.astype(losses.dtype))
                     / jnp.maximum(n_active, 1).astype(losses.dtype))
        return params, mean_loss

    def local_train(global_params, batches, limited):
        return jax.vmap(one_client, in_axes=(None, None, 0, 0))(
            global_params, global_params, batches, limited)

    return local_train


def make_limited_local_train(model, fl: FLConfig, strategy=None):
    """The limited-cohort program of the PARTITIONED client plane.

    Returns local_train(global_params, batches) -> (client_params
    (L, ...), mean_loss (L,)) for a group of cohorts that are ALL
    computing-limited. Generalizes ``make_fes_local_train`` through the
    strategy's client hooks:

      * ``limited_mode == "classifier"`` (AMA-FES): classifier-only
        differentiation — the body backward is never traced, so limited
        devices pay forward + classifier backward only (Eq. 3), instead
        of the masked plane's computed-then-zeroed full backward;
      * ``limited_mode == "full"`` (FedProx, base): the same gradients
        an unlimited cohort takes, over a STATICALLY truncated scan of
        ``static_local_steps`` steps — partial work as a shorter scan,
        not computed-and-discarded gradients.

    Cohorts whose params/losses the caller discards (padding slots of a
    chunk-static partition) are the caller's concern; every row here is
    trained as a real limited cohort.
    """
    strategy = strategy or strategies.resolve(fl)

    if strategy.limited_mode == "classifier":
        grad_fn = jax.value_and_grad(fes_lib.fes_loss_fn(model))

        def one_client(params0, global_params, batches):
            n_steps = jax.tree.leaves(batches)[0].shape[0]
            n_active = min(strategy.static_local_steps(n_steps), n_steps)
            batches = jax.tree.map(lambda x: x[:n_active], batches)
            clf0, body = fes_lib.split_params(params0)
            clf_mask, _ = fes_lib.split_params(model.fes_mask(params0))
            clf_global, _ = fes_lib.split_params(global_params)

            def step(clf, mb):
                loss, g = grad_fn(clf, body, mb)
                g = strategy.local_grad_transform(g, clf, clf_global,
                                                  clf_mask, True)
                return _sgd(clf, g, fl.lr), loss

            clf, losses = jax.lax.scan(step, clf0, batches)
            return fes_lib.merge_params(clf, body), jnp.mean(losses)

    else:  # "full": unlimited gradients over the truncated step budget
        grad_fn = jax.value_and_grad(model.loss)

        def one_client(params0, global_params, batches):
            mask = model.fes_mask(params0)
            n_steps = jax.tree.leaves(batches)[0].shape[0]
            n_active = min(strategy.static_local_steps(n_steps), n_steps)
            batches = jax.tree.map(lambda x: x[:n_active], batches)

            def step(params, mb):
                loss, g = grad_fn(params, mb)
                g = strategy.local_grad_transform(g, params, global_params,
                                                  mask, True)
                return _sgd(params, g, fl.lr), loss

            params, losses = jax.lax.scan(step, params0, batches)
            return params, jnp.mean(losses)

    def local_train(global_params, batches):
        return jax.vmap(one_client, in_axes=(None, None, 0))(
            global_params, global_params, batches)

    return local_train


def make_partitioned_local_train(model, fl: FLConfig, strategy=None):
    """The PARTITIONED mixed-cohort client plane.

    Returns local_train(global_params, batches, sched) -> (client_params
    (C, ...), mean_loss (C,)) — the same contract as the masked plane,
    but each round's cohorts are grouped by limited-ness (the host-side
    ``data.pipeline.partition_plan`` arrays riding in ``sched``) and
    dispatched as TWO vmapped programs: the full/masked program over the
    ``part_full_idx`` group and the classifier-only / truncated program
    (``make_limited_local_train``) over the ``part_lim_idx`` group. The
    stacked outputs are scattered back into cohort-slot order, so the
    fused server update downstream is oblivious to the split.

    Group widths are STATIC per compiled program (they come in as array
    shapes): per chunk, the limited program takes the chunk-minimum
    limited count and overflow limited cohorts run the masked program
    (still correct — just unreduced); a 1-round chunk therefore gets the
    exact per-round split.
    """
    strategy = strategy or strategies.resolve(fl)
    full_train = make_local_train(model, fl, strategy)
    lim_train = make_limited_local_train(model, fl, strategy)

    def local_train(global_params, batches, sched):
        full_idx = sched["part_full_idx"]
        lim_idx = sched["part_lim_idx"]
        src_row = sched["part_src_row"]
        from_lim = sched["part_from_lim"]
        U, L = full_idx.shape[0], lim_idx.shape[0]
        if U:
            f_params, f_loss = full_train(
                global_params,
                jax.tree.map(lambda x: x[full_idx], batches),
                sched["limited"][full_idx])
        if L:
            l_params, l_loss = lim_train(
                global_params,
                jax.tree.map(lambda x: x[lim_idx], batches))
        if not L:
            return (jax.tree.map(lambda f: f[src_row], f_params),
                    f_loss[src_row])
        if not U:
            return (jax.tree.map(lambda l: l[src_row], l_params),
                    l_loss[src_row])

        def scatter(f, l):
            fr = f[jnp.minimum(src_row, U - 1)]
            lr = l[jnp.minimum(src_row, L - 1)]
            sel = from_lim.reshape(from_lim.shape + (1,) * (fr.ndim - 1))
            return jnp.where(sel, lr, fr)

        return (jax.tree.map(scatter, f_params, l_params),
                scatter(f_loss, l_loss))

    return local_train


def make_fes_local_train(model, fl: FLConfig):
    """STATIC FES local training: classifier-only differentiation.

    The body backward is never traced — this is the lowering used to show
    the FES computation reduction in the dry-run/roofline.
    """
    loss_fn = fes_lib.fes_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn)

    def one_client(params0, batches):
        clf0, body = fes_lib.split_params(params0)

        def step(clf, mb):
            loss, g = grad_fn(clf, body, mb)
            return _sgd(clf, g, fl.lr), loss

        clf, losses = jax.lax.scan(step, clf0, batches)
        return fes_lib.merge_params(clf, body), jnp.mean(losses)

    def local_train(global_params, batches, limited=None):
        del limited
        return jax.vmap(one_client, in_axes=(None, 0))(global_params, batches)

    return local_train
