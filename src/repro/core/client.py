"""Client-side local training (paper Alg. 1, lines 11-16).

One engine serves both scales:
  * paper scale — m=10 selected clients vmapped, e local epochs;
  * pod scale  — C cohorts, stacked params sharded over the "client" mesh
    axis; no cross-client collectives inside the local scan (this is the
    defining difference from data-parallel training).

Algorithm behaviour is injected through the ServerStrategy client hooks
(``local_grad_transform``, ``local_steps``) — the AMA family masks FES
gradients, FedProx adds the proximal pull (Eq. 4) and runs partial work
on limited devices; this module contains no per-algorithm branching.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import fes as fes_lib
from repro.core import strategies


def make_local_train(model, fl: FLConfig, strategy=None):
    """Returns local_train(global_params, batches, limited) ->
    (client_params (C, ...), mean_loss (C,)).

    batches: pytree with leading (C, steps, batch, ...) axes.
    limited: (C,) bool — FES-limited cohorts (dynamic mask mode).
    """
    strategy = strategy or strategies.resolve(fl)
    grad_fn = jax.value_and_grad(model.loss)

    def one_client(params0, global_params, batches, limited):
        mask = model.fes_mask(params0)
        n_steps = jax.tree.leaves(batches)[0].shape[0]
        n_active = strategy.local_steps(n_steps, limited)

        def step(carry, mb):
            params, i = carry
            loss, g = grad_fn(params, mb)
            g = strategy.local_grad_transform(g, params, global_params,
                                              mask, limited)
            active = i < n_active
            new_params = jax.tree.map(
                lambda p, gi: jnp.where(
                    active,
                    (p.astype(jnp.float32)
                     - fl.lr * gi.astype(jnp.float32)), p.astype(jnp.float32)
                ).astype(p.dtype),
                params, g)
            return (new_params, i + 1), loss

        (params, _), losses = jax.lax.scan(
            step, (params0, jnp.int32(0)), batches)
        return params, jnp.mean(losses)

    def local_train(global_params, batches, limited):
        return jax.vmap(one_client, in_axes=(None, None, 0, 0))(
            global_params, global_params, batches, limited)

    return local_train


def make_fes_local_train(model, fl: FLConfig):
    """STATIC FES local training: classifier-only differentiation.

    The body backward is never traced — this is the lowering used to show
    the FES computation reduction in the dry-run/roofline.
    """
    loss_fn = fes_lib.fes_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn)

    def one_client(params0, batches):
        clf0, body = fes_lib.split_params(params0)

        def step(clf, mb):
            loss, g = grad_fn(clf, body, mb)
            clf = jax.tree.map(
                lambda p, gi: (p.astype(jnp.float32)
                               - fl.lr * gi.astype(jnp.float32)).astype(p.dtype),
                clf, g)
            return clf, loss

        clf, losses = jax.lax.scan(step, clf0, batches)
        return fes_lib.merge_params(clf, body), jnp.mean(losses)

    def local_train(global_params, batches, limited=None):
        del limited
        return jax.vmap(one_client, in_axes=(None, 0))(global_params, batches)

    return local_train
