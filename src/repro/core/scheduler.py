"""Deterministic heterogeneity simulator (paper §V settings).

Generates, from a seed, the per-round schedule the paper's environment
implies: which clients are selected (m of K), which are computing-limited
(ratio p, a FIXED subset of devices, as in the paper), and which uploads are
delayed (prob. p_delay, delay ~ U{1..max_delay}).

The schedule is data, not code: the same compiled round consumes any
scenario (moderate 30% / severe 70%, max delay 5/10/15...).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import FLConfig


@dataclass
class RoundSchedule:
    selected: np.ndarray    # (m,) client indices
    limited: np.ndarray     # (m,) bool — computing-limited (FES) clients
    delayed: np.ndarray     # (m,) bool — upload delayed
    delays: np.ndarray      # (m,) int32 in [1, max_delay] (1 where not delayed)


class HeterogeneitySchedule:
    def __init__(self, fl: FLConfig):
        self.fl = fl
        rng = np.random.RandomState(fl.seed)
        # fixed computing-limited subset (paper: a device *is* limited)
        k = int(round(fl.p_limited * fl.num_clients))
        self.limited_set = set(
            rng.choice(fl.num_clients, size=k, replace=False).tolist())

    def round(self, t: int) -> RoundSchedule:
        fl = self.fl
        rng = np.random.RandomState(fl.seed * 1_000_003 + t)  # reproducible per-round
        sel = rng.choice(fl.num_clients, size=fl.clients_per_round,
                         replace=False).astype(np.int32)
        limited = np.array([i in self.limited_set for i in sel])
        if fl.max_delay > 0 and fl.p_delay > 0:
            delayed = rng.rand(fl.clients_per_round) < fl.p_delay
            delays = rng.randint(1, fl.max_delay + 1,
                                 size=fl.clients_per_round).astype(np.int32)
        else:
            delayed = np.zeros(fl.clients_per_round, bool)
            delays = np.ones(fl.clients_per_round, np.int32)
        delays = np.where(delayed, delays, 1).astype(np.int32)
        return RoundSchedule(sel, limited, delayed, delays)

    def batch(self, t0: int, n_rounds: int) -> dict[str, np.ndarray]:
        """Stacked (n_rounds, C) schedule arrays for the fused scan engine.

        Row i is BIT-IDENTICAL to ``round(t0 + i)``: each round owns an
        independent RNG stream keyed on its absolute index, so the
        schedule of round t cannot depend on how (or whether) it was
        batched — the contract the scan-vs-python-loop equivalence test
        relies on. The per-round draws therefore cannot be collapsed
        into one vectorised stream; the vectorisation is the output
        layout (stacked arrays as scan data), produced from the one
        authoritative ``round()`` implementation.
        """
        rows = [self.round(t0 + i) for i in range(n_rounds)]
        return {"selected": np.stack([r.selected for r in rows]),
                "limited": np.stack([r.limited for r in rows]),
                "delayed": np.stack([r.delayed for r in rows]),
                "delays": np.stack([r.delays for r in rows])}
