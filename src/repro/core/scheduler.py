"""Deterministic heterogeneity simulator (paper §V settings).

Generates, from a seed, the per-round schedule the paper's environment
implies: which clients are selected (m of K), which are computing-limited
(ratio p, a FIXED subset of devices, as in the paper), and which uploads are
delayed (prob. p_delay, delay ~ U{1..max_delay}).

The schedule is data, not code: the same compiled round consumes any
scenario (moderate 30% / severe 70%, max delay 5/10/15...).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import FLConfig


@dataclass
class RoundSchedule:
    selected: np.ndarray    # (m,) client indices
    limited: np.ndarray     # (m,) bool — computing-limited (FES) clients
    delayed: np.ndarray     # (m,) bool — upload delayed
    delays: np.ndarray      # (m,) int32 in [1, max_delay] (1 where not delayed)


class HeterogeneitySchedule:
    def __init__(self, fl: FLConfig):
        self.fl = fl
        rng = np.random.RandomState(fl.seed)
        # fixed computing-limited subset (paper: a device *is* limited)
        k = int(round(fl.p_limited * fl.num_clients))
        self.limited_set = set(
            rng.choice(fl.num_clients, size=k, replace=False).tolist())
        self._rng = np.random.RandomState(fl.seed + 1)

    def round(self, t: int) -> RoundSchedule:
        fl = self.fl
        rng = np.random.RandomState(fl.seed * 1_000_003 + t)  # reproducible per-round
        sel = rng.choice(fl.num_clients, size=fl.clients_per_round,
                         replace=False).astype(np.int32)
        limited = np.array([i in self.limited_set for i in sel])
        if fl.max_delay > 0 and fl.p_delay > 0:
            delayed = rng.rand(fl.clients_per_round) < fl.p_delay
            delays = rng.randint(1, fl.max_delay + 1,
                                 size=fl.clients_per_round).astype(np.int32)
        else:
            delayed = np.zeros(fl.clients_per_round, bool)
            delays = np.ones(fl.clients_per_round, np.int32)
        delays = np.where(delayed, delays, 1).astype(np.int32)
        return RoundSchedule(sel, limited, delayed, delays)
