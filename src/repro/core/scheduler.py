"""Backward-compat shim over the environment subsystem (``repro.env``).

The seed's deterministic heterogeneity simulator lives on as the
``bernoulli`` environment (``repro.env.bernoulli`` — a bit-identical
port, enforced by tests/test_env.py); ``HeterogeneitySchedule`` is now a
thin wrapper over it so existing callers keep working. New code should
use ``repro.env.resolve(fl)`` and pick a channel model / scenario.

The schedule is data, not code: the same compiled round consumes any
scenario (moderate 30% / severe 70%, bursty fading, bandwidth-limited,
trace replay...).
"""
from __future__ import annotations

from repro.configs.base import FLConfig
from repro.env import RoundSchedule  # noqa: F401  (re-export, old import path)
from repro.env import get as _get_env


class HeterogeneitySchedule:
    """Thin wrapper: the seed API over ``env.get("bernoulli")``."""

    def __init__(self, fl: FLConfig):
        self.fl = fl
        self._env = _get_env("bernoulli")(fl)
        # seed-era attribute, still used by callers/tests
        self.limited_set = self._env.devices.limited_set

    def round(self, t: int) -> RoundSchedule:
        return self._env.round(t)

    def batch(self, t0: int, n_rounds: int):
        """Stacked (n_rounds, m) schedule arrays for the fused scan
        engine; row i is bit-identical to ``round(t0 + i)`` (the
        contract lives in ``repro.env.base``)."""
        return self._env.batch(t0, n_rounds)
