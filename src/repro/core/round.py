"""The jitted federated round + the fused multi-round scan engine.

``make_round_step`` is the paper's Algorithm 1 as a single ``train_step``
suitable for pjit on the production mesh: C client cohorts train in
parallel on the "client" mesh axis with NO cross-client collectives
during local steps; the server aggregation — one fused server-plane
kernel pass over the client axis (``strategy.fused_server_update``) —
is the only cross-cohort communication of the round — the paper's
rare-global-aggregation pattern, TPU-native.

``make_train_loop`` goes one step further: it rolls N rounds into one
``jax.lax.scan`` over precomputed schedule arrays, so an entire run
compiles to ONE XLA program — no per-round Python dispatch, no per-round
host sync, and the state carry is donated so the global model is updated
in place.

THE SCHEDULE CONTRACT: every environment in the ``repro.env`` registry
emits stacked ``{selected, limited, delayed, delays, data_sizes}``
arrays via ``Environment.batch(t0, n)`` (row i bit-identical to
``round(t0 + i)``); ``as_scan_scheds`` lifts that numpy dict onto the
device in the exact leaf set the scan body consumes. Any scenario —
i.i.d. Bernoulli, bursty Gilbert-Elliott fading, bandwidth deadlines,
trace replay — therefore drives this engine unchanged.

All algorithm behaviour comes from the ServerStrategy registry
(``repro.core.strategies``); this module contains no per-algorithm or
per-environment branching.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import strategies
from repro.core.client import (make_fes_local_train, make_local_train,
                               make_partitioned_local_train)
from repro.sharding.ctx import axis_size, constrain_leading

#: partitioned-client-plane dispatch arrays (data.pipeline.partition_plan)
#: that ride the schedule dict when fl.client_plane == "partitioned"
PARTITION_KEYS = ("part_full_idx", "part_lim_idx", "part_src_row",
                  "part_from_lim")


def as_scan_scheds(sb: dict) -> dict:
    """Device-ready scan schedules from a stacked ``Environment.batch``
    dict: keeps exactly the leaves the round body consumes (``selected``
    is host-side — it addresses client datasets, not cohort slots) and
    re-types them for the scan carry. Partition-plan arrays (present
    when the partitioned client plane is staged) pass through."""
    out = {"limited": jnp.asarray(sb["limited"]),
           "delayed": jnp.asarray(sb["delayed"]),
           "delays": jnp.asarray(sb["delays"]),
           "data_sizes": jnp.asarray(sb["data_sizes"], jnp.float32)}
    for k in PARTITION_KEYS:
        if k in sb:
            out[k] = jnp.asarray(sb[k])
    return out


def init_state(model, fl: FLConfig, key, strategy=None):
    """Round-loop carry: global params, round index, strategy aux state
    (async ring buffer, fedopt moments, ... — {} for stateless rules).
    With a comm plane active (``fl.comm_plane != "none"``) the
    error-feedback residual rides the same carry under ``aux["comm"]``
    — one (C, N_g) f32 array per dtype group, C the stacked cohort
    width — so checkpoints/resume carry it like any strategy state."""
    strategy = strategy or strategies.resolve(fl)
    params = model.init(key)
    aux = strategy.init_state(params)
    from repro import comm
    plane = comm.resolve(fl)
    if plane is not None:
        res = plane.init_residual(params, fl.clients_per_round)
        if res:
            aux = dict(aux)
            aux["comm"] = res
    return {"params": params, "t": jnp.zeros((), jnp.int32), "aux": aux}


def make_round_step(model, fl: FLConfig, strategy=None):
    """Returns round_step(state, batch, sched) -> (state, metrics).

    batch: pytree with leading (C, steps, b, ...) axes.
    sched: {"limited","delayed","delays","data_sizes"} each (C,); with
    ``fl.client_plane = "partitioned"`` also the ``PARTITION_KEYS``
    dispatch arrays from ``data.pipeline.partition_plan`` (ChunkRunner
    merges them in when it stages a chunk).
    """
    strategy = strategy or strategies.resolve(fl)
    if fl.fes_static:
        plane = make_fes_local_train(model, fl)
        local_train = lambda g, b, sched: plane(g, b, sched["limited"])
    elif getattr(fl, "client_plane", "masked") == "partitioned":
        # two vmapped programs per round, grouped by limited-ness (the
        # staging layer's partition_plan arrays ride in ``sched``) and
        # scattered back into cohort-slot order before the server update
        plane = make_partitioned_local_train(model, fl, strategy)

        def local_train(g, b, sched):
            if "part_src_row" not in sched:
                raise KeyError(
                    "client_plane='partitioned' needs the partition-plan "
                    "arrays in sched — stage through ChunkRunner or merge "
                    "data.pipeline.partition_plan(limited) yourself")
            return plane(g, b, sched)
    elif getattr(fl, "client_plane", "masked") == "masked":
        plane = make_local_train(model, fl, strategy)
        local_train = lambda g, b, sched: plane(g, b, sched["limited"])
    else:
        raise ValueError(f"unknown client_plane {fl.client_plane!r}; "
                         "expected 'masked' or 'partitioned'")

    # extended telemetry (fl.extended_metrics): the per-round series of
    # repro.obs.metrics ride the scan ys — computed from values the round
    # already materializes, so enabling them never changes the params
    # stream (the engine's bit-identity nets gate this)
    extended = bool(getattr(fl, "extended_metrics", False))
    if extended:
        from repro.obs.metrics import payload_bytes, round_metrics

    # comm plane (fl.comm_plane): compress the stacked client deltas
    # BEFORE the server reduction. None for "none" — every branch below
    # is then untaken and the traced program is the pre-comm one
    # byte-for-byte (bit-identity gated by tests/test_comm_plane.py).
    from repro import comm
    comm_plane = comm.resolve(fl)

    def round_step(state, batch, sched, _tap=None):
        t = state["t"]
        prev_global = state["params"]
        # stacked client axis over the FL mesh ("client"); no-op off-mesh
        batch = constrain_leading(batch, "client")
        client_params, losses = local_train(prev_global, batch, sched)
        client_params = constrain_leading(client_params, "client")
        # compressed uplink: quantize/sparsify the deltas (plus carried
        # error-feedback residual), then hand the SERVER only what the
        # wire would deliver. The residual is comm-plane state, not
        # strategy state — popped here so strategies never see it.
        srv_aux = state["aux"]
        groups = new_res = None
        if comm_plane is not None:
            srv_aux = {k: v for k, v in state["aux"].items() if k != "comm"}
            groups, new_res = comm_plane.compress(
                t, prev_global, client_params, state["aux"].get("comm", {}))
        # pre-reduce the stacked client axis when it is actually
        # distributed (fl.client_reduce: "auto" checks the ACTIVE mesh at
        # trace time; "force" for CPU equivalence tests): the weighted
        # delta reduction happens BEFORE the server plane, so the
        # per-round collective moves N, not C x N, bytes. On a 1-device
        # mesh "auto" stays off and the fused plane keeps its
        # bit-identity contract.
        mode = getattr(fl, "client_reduce", "auto")
        new_params = aux = None
        if mode == "force" or (mode == "auto" and axis_size("client") > 1):
            cp = (comm_plane.reconstruct(prev_global, groups)
                  if comm_plane is not None else client_params)
            out = strategy.reduced_server_update(
                t, prev_global, cp, sched, srv_aux)
            if out is not NotImplemented:
                new_params, aux = out
        elif mode not in ("auto", "off"):
            raise ValueError(f"unknown client_reduce {mode!r}; "
                             "expected 'auto' | 'off' | 'force'")
        if new_params is None and comm_plane is not None:
            # fused dequantize-accumulate: the mix family consumes the
            # compressed payload in-kernel; strategies whose update is
            # not linear in the deltas return NotImplemented and take
            # the densified fallback below
            out = strategy.compressed_server_update(
                t, prev_global, groups, sched, srv_aux)
            if out is not NotImplemented:
                new_params, aux = out
            else:
                client_params = comm_plane.reconstruct(prev_global, groups)
        if new_params is None:
            # ONE fused server-plane pass: staleness weights, delta
            # accumulation, ring-buffer mix and (fedopt) server-Adam in
            # a single kernel dispatch (fl.server_plane selects the impl)
            new_params, aux = strategy.fused_server_update(
                t, prev_global, client_params, sched, srv_aux)
        if new_res:
            aux = dict(aux)
            aux["comm"] = new_res
        on_time = jnp.logical_not(sched["delayed"])
        metrics = {"loss": jnp.mean(losses),
                   "n_on_time": jnp.sum(on_time.astype(jnp.int32))}
        if extended:
            # the metric taps must OBSERVE the params stream, not
            # participate in it: any extra consumer of the LIVE scan
            # carry (prev params / aux) lets XLA rewrite the update
            # algebra it feeds and shifts the params by 1-2 ulp (and
            # optimization_barrier does not survive this backend's
            # pipeline). ``_tap`` is the shadow copy of the previous
            # round's {params, aux} that make_train_loop threads through
            # a dedicated carry slot — equal by construction, but a
            # separate buffer with no consumers in the round math, so
            # the metrics-off program is untouched. Absent a tap (bare
            # per-round jit outside the engine) the live carry is used:
            # a single-round program has no cross-round fusion to
            # perturb.
            tap = _tap if _tap is not None else {"params": prev_global,
                                                 "aux": state["aux"]}
            metrics.update(round_metrics(
                fl, strategy, t, tap["params"], client_params,
                new_params, sched, tap["aux"],
                payload=payload_bytes(prev_global),
                payload_compressed=(
                    comm_plane.payload_bytes(prev_global)
                    if comm_plane is not None else None)))
        return {"params": new_params, "t": t + 1, "aux": aux}, metrics

    return round_step


def make_train_loop(model, fl: FLConfig, strategy=None, *,
                    per_round_batch: bool = False, donate: bool = True):
    """Fused N-round engine: one XLA program for the whole run.

    Returns train_loop(state, batch, scheds) -> (state, metrics) where
    ``scheds`` leaves carry a leading (n_rounds,) axis (the stacked
    output of ``Environment.batch`` / ``as_scan_scheds``) and metrics come back
    stacked per round. With ``per_round_batch`` the batch pytree also
    carries a leading (n_rounds,) axis (fresh data every round — the
    correctness-equivalence configuration); without it the same batch is
    re-fed each round (the throughput configuration — no O(N) input
    staging). ``donate`` donates the state carry buffers to XLA so the
    global model (and at LLM scale that is the whole HBM budget) is
    updated in place; pass False when the caller needs the input state
    afterwards.

    With ``fl.extended_metrics`` the returned callable takes a fourth
    argument: ``train_loop(state, batch, scheds, tap0)`` where ``tap0``
    is a device COPY of the initial ``{"params", "aux"}`` (separate
    buffers — do not pass the live state arrays, that defeats donation
    and the CSE isolation; see the comment at the extended branch).
    """
    round_step = make_round_step(model, fl, strategy)
    extended = bool(getattr(fl, "extended_metrics", False))

    if extended:
        # shadow-tap plumbing: the telemetry reads the previous round's
        # {params, aux} through a dedicated carry slot seeded from the
        # EXTRA ``tap0`` argument (a caller-side device copy of the
        # initial state — ChunkRunner makes it). The tap must enter the
        # program as its own parameter: seeding it from ``state`` inside
        # the program makes it the same SSA value as the (donated) live
        # carry, and at trip-count-1 XLA value-numbers the two slots
        # back together, re-fusing the metric norms with the server mix
        # and shifting the params by 1 ulp. A distinct parameter cannot
        # be CSE'd away, so the live carry keeps exactly the consumer
        # set of the metrics-off program — the bit-identity contract
        # (see round_step).
        def train_loop_ext(state, batch, scheds, tap0):
            def body(carry, xs):
                st, tap = carry
                b, sc = xs if per_round_batch else (batch, xs)
                new_st, m = round_step(st, b, sc, tap)
                return (new_st, {"params": new_st["params"],
                                 "aux": new_st["aux"]}), m
            xs = (batch, scheds) if per_round_batch else scheds
            (state, _), metrics = jax.lax.scan(body, (state, tap0), xs)
            return state, metrics
        return jax.jit(train_loop_ext,
                       donate_argnums=(0,) if donate else ())

    def train_loop(state, batch, scheds):
        if per_round_batch:
            def body(st, xs):
                b, sc = xs
                return round_step(st, b, sc)
            return jax.lax.scan(body, state, (batch, scheds))

        def body(st, sc):
            return round_step(st, batch, sc)
        return jax.lax.scan(body, state, scheds)

    return jax.jit(train_loop, donate_argnums=(0,) if donate else ())


def make_train_step_for_lowering(model, fl: FLConfig):
    """Flat-signature variant for .lower(): (params, [aux,] t, batch,
    sched) -> same. Keeps the dry-run input_specs simple. Off-TPU the
    fused server plane lowers as the flat oracle (see
    ``kernels.server_plane._route``), so the dry-run's HLO cost analysis
    sees the real fused op sequence, not interpreter emulation."""
    from repro import comm
    strategy = strategies.resolve(fl)
    round_step = make_round_step(model, fl, strategy)
    plane = comm.resolve(fl)

    # a comm plane with error feedback makes aux non-empty even for
    # stateless strategies (the residual rides aux["comm"])
    if strategy.stateful or (plane is not None and plane.error_feedback):
        def step(params, aux, t, batch, sched):
            state = {"params": params, "t": t, "aux": aux}
            out, metrics = round_step(state, batch, sched)
            return out["params"], out["aux"], metrics
        return step

    def step(params, t, batch, sched):
        state = {"params": params, "t": t, "aux": {}}
        out, metrics = round_step(state, batch, sched)
        return out["params"], metrics
    return step
