"""The jitted federated round — one XLA program per round (pod scale).

This is the paper's Algorithm 1 as a single ``train_step`` suitable for
pjit on the production mesh: C client cohorts train in parallel on the
"client" mesh axis with NO cross-client collectives during local steps;
the AMA aggregation (one weighted reduction over the client axis + mix
with omega_{t-1}) is the only cross-cohort communication of the round —
the paper's rare-global-aggregation pattern, TPU-native.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import async_ama
from repro.core.ama import ama_aggregate, fedavg_aggregate
from repro.core.client import make_fes_local_train, make_local_train


def init_state(model, fl: FLConfig, key):
    params = model.init(key)
    state = {"params": params, "t": jnp.zeros((), jnp.int32)}
    if fl.max_delay > 0:
        state["queue"] = async_ama.init_queue(fl, params)
    return state


def make_round_step(model, fl: FLConfig):
    """Returns round_step(state, batch, sched) -> (state, metrics).

    batch: pytree with leading (C, steps, b, ...) axes.
    sched: {"limited","delayed","delays","data_sizes"} each (C,).
    """
    local_train = (make_fes_local_train(model, fl) if fl.fes_static
                   else make_local_train(model, fl))

    def round_step(state, batch, sched):
        t = state["t"]
        prev_global = state["params"]
        client_params, losses = local_train(prev_global, batch,
                                            sched["limited"])
        on_time = jnp.logical_not(sched["delayed"])
        new_state = dict(state, t=t + 1)

        if fl.algorithm == "fedavg":
            # naive FL: drop limited AND delayed clients, no mixing
            keep = jnp.logical_and(on_time,
                                   jnp.logical_not(sched["limited"]))
            new_params = fedavg_aggregate(prev_global, client_params,
                                          sched["data_sizes"], keep)
        elif fl.algorithm == "fedprox":
            # FedProx aggregates on-time clients, no mixing
            new_params = fedavg_aggregate(prev_global, client_params,
                                          sched["data_sizes"], on_time)
        elif fl.max_delay > 0:
            queue = async_ama.enqueue(fl, state["queue"], t, client_params,
                                      sched["delayed"], sched["delays"])
            new_params, queue = async_ama.async_ama_aggregate(
                fl, t, prev_global, client_params, sched["data_sizes"],
                on_time, queue)
            new_state["queue"] = queue
        else:
            new_params = ama_aggregate(fl, t, prev_global, client_params,
                                       sched["data_sizes"], on_time)

        new_state["params"] = new_params
        metrics = {"loss": jnp.mean(losses),
                   "n_on_time": jnp.sum(on_time.astype(jnp.int32))}
        return new_state, metrics

    return round_step


def make_train_step_for_lowering(model, fl: FLConfig):
    """Flat-signature variant for .lower(): (params, [queue,] t, batch,
    sched) -> same. Keeps the dry-run input_specs simple."""
    round_step = make_round_step(model, fl)

    if fl.max_delay > 0:
        def step(params, queue, t, batch, sched):
            state = {"params": params, "queue": queue, "t": t}
            out, metrics = round_step(state, batch, sched)
            return out["params"], out["queue"], metrics
        return step

    def step(params, t, batch, sched):
        state = {"params": params, "t": t}
        out, metrics = round_step(state, batch, sched)
        return out["params"], metrics
    return step
