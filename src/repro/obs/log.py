"""Host-side metrics collection: the schema-versioned JSONL sink.

One run = one JSONL file (``--metrics-out``):

  {"kind": "header", "schema": 2, "provenance": {...}, "config": {...},
   "payload_bytes": N, "resumed_at": t | null}
  {"kind": "round", "t": 0, "loss": ..., "n_on_time": ...,
   "n_limited": ..., "n_delayed": ..., "mean_delay": ...,
   "stale_hist": [...], "alpha_eff": ..., "delta_norm": ...,
   "update_norm": ..., "bytes_on_wire": ...}          # one per round
  {"kind": "eval", "t": 5, "test_acc": ..., "test_loss": ...}
  {"kind": "phases", "phases": {"stage": {"seconds": ..., "calls": ...},
   "compile": ..., "scan_dispatch": ..., "eval": ..., "checkpoint": ...}}
  {"kind": "serve", "id": 0, "new_tokens": 16, "queue_s": ...,
   "prefill_s": ..., "decode_s": ..., "total_s": ...}  # one per request
  {"kind": "serve_summary", "requests": N, "new_tokens": ...,
   "tokens_per_s": ..., "p50_ms": ..., "p95_ms": ..., "p99_ms": ...}

Round rows are pure functions of the round they describe (absolute
``t``, device-computed values), so a resumed run's file is bit-identical
to the tail of the uninterrupted run's file — the JSONL analogue of the
engine's checkpoint bit-identity contract (gated in tests/test_obs.py).
Wall-clock rows ("phases") and the header are explicitly excluded from
that contract.

``validate_rows`` is the schema checker behind
``scripts/check_metrics.py`` (the CI gate on launcher-emitted JSONL).
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

#: v2 adds the serving-plane rows ("serve", "serve_summary"); v3 adds
#: the comm-plane wire fields on round rows (bytes_on_wire_compressed,
#: compression_ratio — optional, like every extended round metric);
#: v1/v2 files (without them) remain readable
SCHEMA_VERSION = 3
SUPPORTED_SCHEMAS = (1, 2, 3)

#: required keys per row kind (extended round metrics are optional —
#: a base run logs only loss/participation)
REQUIRED = {
    "header": ("schema",),
    "round": ("t", "loss", "n_on_time"),
    "eval": ("t", "test_acc", "test_loss"),
    "phases": ("phases",),
    "serve": ("id", "new_tokens"),
    "serve_summary": ("requests", "tokens_per_s"),
}
KINDS = tuple(REQUIRED)

#: per-request latency series a serve row may carry (all seconds)
SERVE_LATENCY_KEYS = ("queue_s", "prefill_s", "decode_s", "total_s")


def _py(x):
    """JSON-ready scalar/list from a numpy/jax value."""
    a = np.asarray(x)
    if a.ndim == 0:
        return a.item()
    return a.tolist()


class MetricsLogger:
    """Streams run telemetry to a JSONL file (or collects in memory
    with ``path=None`` — the tests' sink). The engine calls ``header``
    once, ``rounds`` per executed chunk, ``eval`` per eval point and
    ``phases`` when a run segment finishes."""

    def __init__(self, path: str | None):
        self.path = path
        self.rows: list[dict] = []        # in-memory mirror (path=None
        self._f = open(path, "w") if path else None   # keeps only this)
        self._header_done = False

    # ------------------------------------------------------------ rows --
    def _emit(self, row: dict) -> None:
        if self._f is not None:
            self._f.write(json.dumps(row) + "\n")
            self._f.flush()
        else:
            self.rows.append(row)

    def header(self, fl=None, *, payload: int | None = None,
               resumed_at: int | None = None, extra: dict | None = None
               ) -> None:
        """The one-per-file header row (idempotent: later calls no-op,
        so engine re-entry across run() calls appends rounds, not
        headers)."""
        if self._header_done:
            return
        self._header_done = True
        from repro.obs.provenance import provenance
        cfg = (dataclasses.asdict(fl) if dataclasses.is_dataclass(fl)
               else dict(fl or {}))
        self._emit({"kind": "header", "schema": SCHEMA_VERSION,
                    "provenance": provenance(), "config": cfg,
                    "payload_bytes": payload, "resumed_at": resumed_at,
                    **(extra or {})})

    def rounds(self, t0: int, metrics: dict) -> None:
        """One row per round of a chunk: ``metrics`` leaves carry a
        leading (n,) axis (the stacked scan ys back on host). ``t0`` is
        the absolute round counter ENTERING the chunk; rows are labeled
        by the round they complete (t0+1 .. t0+n), the same 1-indexed
        absolute convention as eval rows, ``resumed_at`` and
        ``History.eval_rounds`` — so a resumed run's tail is directly
        comparable to the uninterrupted run's."""
        n = len(np.asarray(metrics["loss"]))
        for i in range(n):
            row = {"kind": "round", "t": int(t0) + i + 1}
            for k, v in metrics.items():
                row[k] = _py(np.asarray(v)[i])
            self._emit(row)

    def eval(self, t: int, test_acc: float, test_loss: float) -> None:
        self._emit({"kind": "eval", "t": int(t),
                    "test_acc": float(test_acc),
                    "test_loss": float(test_loss)})

    def phases(self, times) -> None:
        """Serialize a ``PhaseTimes`` summary (or a plain dict)."""
        summary = times.summary() if hasattr(times, "summary") else times
        self._emit({"kind": "phases", "phases": summary})

    def serve(self, result: dict) -> None:
        """One per-request serving row (engine result dict: id,
        new_tokens, queue_s/prefill_s/decode_s/total_s). The decoded
        token ids are NOT logged — telemetry, not transcripts."""
        row = {"kind": "serve", "id": int(result["id"]),
               "new_tokens": int(result["new_tokens"])}
        for k in SERVE_LATENCY_KEYS:
            if k in result:
                row[k] = round(float(result[k]), 6)
        self._emit(row)

    def serve_summary(self, summary: dict) -> None:
        """The one-per-run aggregate: tokens/sec + latency percentiles
        (engine ``last_summary`` dict)."""
        self._emit({"kind": "serve_summary", **summary})

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ----------------------------------------------------------------------
# reading + validation (report CLI, scripts/check_metrics.py)
# ----------------------------------------------------------------------

def read_rows(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: not JSON ({e})") from None
    return rows


def validate_rows(rows: list[dict]) -> list[str]:
    """Schema violations as human-readable strings ([] = valid).

    Checks: a leading header row with a known schema version, known row
    kinds, required keys present with sane types, round indices strictly
    increasing, eval rows aligned to logged rounds."""
    errs = []
    if not rows:
        return ["empty file (no header row)"]
    if rows[0].get("kind") != "header":
        errs.append("first row must be kind=header, got "
                    f"{rows[0].get('kind')!r}")
    elif rows[0].get("schema") not in SUPPORTED_SCHEMAS:
        errs.append(f"unsupported schema {rows[0].get('schema')!r} "
                    f"(reader supports {SUPPORTED_SCHEMAS})")
    prev_t = None
    for i, row in enumerate(rows):
        kind = row.get("kind")
        if kind not in KINDS:
            errs.append(f"row {i}: unknown kind {kind!r}")
            continue
        if kind == "header" and i > 0:
            errs.append(f"row {i}: duplicate header")
        missing = [k for k in REQUIRED[kind] if k not in row]
        if missing:
            errs.append(f"row {i} ({kind}): missing keys {missing}")
            continue
        if kind == "round":
            if not isinstance(row["t"], int):
                errs.append(f"row {i}: round t must be int, got "
                            f"{type(row['t']).__name__}")
            elif prev_t is not None and row["t"] <= prev_t:
                errs.append(f"row {i}: round t={row['t']} not after "
                            f"t={prev_t}")
            else:
                prev_t = row["t"]
            for k in ("loss", "mean_delay", "alpha_eff", "delta_norm",
                      "update_norm", "bytes_on_wire",
                      "bytes_on_wire_compressed", "compression_ratio"):
                if k in row and not isinstance(row[k], (int, float)):
                    errs.append(f"row {i}: {k} must be numeric")
            for k in ("bytes_on_wire_compressed", "compression_ratio"):
                if isinstance(row.get(k), (int, float)) and row[k] < 0:
                    errs.append(f"row {i}: {k} must be >= 0")
            if "stale_hist" in row and not isinstance(row["stale_hist"],
                                                      list):
                errs.append(f"row {i}: stale_hist must be a list")
        if kind == "eval":
            for k in ("test_acc", "test_loss"):
                if not isinstance(row[k], (int, float)):
                    errs.append(f"row {i}: {k} must be numeric")
            if prev_t is not None and row["t"] > prev_t:
                errs.append(f"row {i}: eval at t={row['t']} beyond last "
                            f"logged round t={prev_t}")
        if kind == "serve":
            for k in ("id", "new_tokens"):
                if not isinstance(row[k], int):
                    errs.append(f"row {i}: {k} must be int")
            for k in SERVE_LATENCY_KEYS:
                if k in row and not isinstance(row[k], (int, float)):
                    errs.append(f"row {i}: {k} must be numeric")
                elif isinstance(row.get(k), (int, float)) and row[k] < 0:
                    errs.append(f"row {i}: {k} must be >= 0")
        if kind == "serve_summary":
            for k in ("requests", "new_tokens", "tokens_per_s"):
                if k in row and not isinstance(row[k], (int, float)):
                    errs.append(f"row {i}: {k} must be numeric")
    return errs
