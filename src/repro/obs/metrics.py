"""In-scan round metrics + the shared stability/windowing math.

``round_metrics`` is traced INSIDE the jitted round step (and therefore
inside the fused multi-round ``lax.scan``), so the per-round series ride
the scan ys and come back stacked with zero extra dispatches. Every
quantity is a pure function of values the round already materializes
(the schedule arrays, the stacked client params, the pre/post global
model, the strategy aux state) — enabling it never changes the params
stream (bit-identity gated by tests/test_obs.py).

``stability_stats`` is the ONE implementation of the paper's stability
window (variance of test accuracy over the last ``last`` ROUNDS — not
eval points, which silently diverge from rounds whenever
``eval_every > 1``). ``exec.engine.History`` and the report CLI
(``repro.obs.report``) both call it, which is what makes the report
reproduce ``History.final_accuracy`` / ``stability_variance`` exactly
from a JSONL file alone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

#: per-round metric keys an extended-metrics run emits (beyond the
#: base {"loss", "n_on_time"}); ``stale_hist`` is a vector of
#: ``max_delay + 1`` staleness-bin counts, everything else a scalar
ROUND_METRIC_KEYS = ("n_limited", "n_delayed", "mean_delay", "stale_hist",
                     "alpha_eff", "delta_norm", "update_norm",
                     "bytes_on_wire", "bytes_on_wire_compressed",
                     "compression_ratio")


def payload_bytes(params) -> int:
    """Static bytes of ONE client's model-update upload (the full
    parameter pytree at its stored dtypes). An upper bound under FES —
    a limited client whose body delta is identically zero could ship
    the classifier subtree only; the wire estimate charges the dense
    tree the engine actually moves."""
    return int(sum(x.size * jnp.dtype(x.dtype).itemsize
                   for x in jax.tree.leaves(params)))


def _global_norm(tree) -> jnp.ndarray:
    """f32 l2 norm over every element of every leaf."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def round_metrics(fl, strategy, t, prev_global, client_params, new_params,
                  sched, aux_state, *, payload: int,
                  payload_compressed: int | None = None) -> dict:
    """The extended per-round metric dict (all traced, fixed shapes).

    * participation: ``n_limited`` / ``n_delayed`` cohort counts;
    * staleness: ``mean_delay`` over the delayed cohorts and
      ``stale_hist`` — bincount of delays into ``max_delay + 1`` static
      bins (bin d = cohorts arriving d rounds late);
    * aggregation: ``alpha_eff`` — the strategy's effective
      previous-model mix coefficient this round
      (``ServerStrategy.mix_coefficient``: the realized Eq. 5 / Eq. 10
      alpha for the AMA family, 0 for pure weighted-average rules);
    * magnitudes: ``delta_norm`` — global l2 norm of the stacked
      client deltas (client_params - prev_global over all C cohorts),
      ``update_norm`` — l2 norm of the server step actually taken;
    * wire: ``bytes_on_wire`` = on-time uploads x the static per-client
      payload (delayed cohorts are charged on their arrival round via
      the staleness path they ride); ``bytes_on_wire_compressed`` = the
      same count x the ACTUAL bytes the active comm plane ships
      (``CommPlane.payload_bytes`` — equal to the dense payload when
      ``comm_plane="none"``); ``compression_ratio`` = dense/compressed
      per-client bytes (1.0 for the dense plane, ~4 for q8, ...).
    """
    delayed = sched["delayed"].astype(jnp.float32)
    delays = sched["delays"].astype(jnp.float32)
    n_delayed = jnp.sum(delayed)
    n_on_time = sched["delayed"].shape[0] - n_delayed
    bins = int(max(getattr(fl, "max_delay", 0), 0)) + 1
    d_int = sched["delays"].astype(jnp.int32)
    onehot = (d_int[:, None] == jnp.arange(bins)[None, :]).astype(
        jnp.float32) * delayed[:, None]
    stale_hist = jnp.sum(onehot, axis=0).astype(jnp.int32)
    mean_delay = jnp.sum(delays * delayed) / jnp.maximum(n_delayed, 1.0)
    deltas = jax.tree.map(
        lambda c, p: c.astype(jnp.float32)
        - p.astype(jnp.float32)[None], client_params, prev_global)
    step = jax.tree.map(
        lambda n, p: n.astype(jnp.float32) - p.astype(jnp.float32),
        new_params, prev_global)
    return {
        "n_limited": jnp.sum(sched["limited"].astype(jnp.int32)),
        "n_delayed": n_delayed.astype(jnp.int32),
        "mean_delay": mean_delay,
        "stale_hist": stale_hist,
        "alpha_eff": jnp.asarray(
            strategy.mix_coefficient(t, sched, aux_state), jnp.float32),
        "delta_norm": _global_norm(deltas),
        "update_norm": _global_norm(step),
        "bytes_on_wire": n_on_time * jnp.float32(payload),
        "bytes_on_wire_compressed": n_on_time * jnp.float32(
            payload if payload_compressed is None else payload_compressed),
        "compression_ratio": jnp.float32(
            1.0 if payload_compressed is None
            else payload / max(payload_compressed, 1)),
    }


# ------------------------------------------------------------------
# host-side stability math (pure numpy — shared History/report code)
# ------------------------------------------------------------------

def window_by_rounds(eval_rounds, last: int) -> np.ndarray:
    """Boolean mask over eval points selecting the last ``last`` ROUNDS:
    an eval at absolute round t is in the window iff
    t > max(eval_rounds) - last. With ``eval_every == 1`` this is
    exactly "the last ``last`` eval points"; with a sparser cadence it
    keeps the window a fixed span of ROUNDS instead of silently
    widening it by the cadence factor."""
    rounds = np.asarray(eval_rounds, np.int64)
    if rounds.size == 0:
        return np.zeros((0,), bool)
    return rounds > (rounds.max() - int(last))


def stability_stats(eval_rounds, test_acc, last: int = 50) -> dict:
    """Paper metrics over the last ``last`` rounds: mean accuracy and
    the stability variance (variance of test accuracy in percentage
    points squared). The single implementation behind both
    ``History.final_accuracy``/``stability_variance`` and the report
    CLI."""
    accs = np.asarray(test_acc, np.float64)
    if len(eval_rounds) == len(accs):
        accs = accs[window_by_rounds(eval_rounds, last)]
    else:                     # legacy History with no round indices:
        accs = accs[-last:]   # fall back to counting eval points
    if accs.size == 0:
        return {"final_accuracy": float("nan"),
                "stability_variance": float("nan"), "n_evals": 0}
    return {"final_accuracy": float(np.mean(accs)),
            "stability_variance": float(np.var(accs * 100.0)),
            "n_evals": int(accs.size)}
