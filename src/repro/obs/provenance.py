"""The shared provenance block for every machine-readable artifact.

A benchmark regression gate that only says "sim_engine dropped below
0.9x" forces archaeology; one that says "baseline was jax 0.4.30 on
cpu x1 at sha 4178aca, fresh is jax 0.4.38 on cpu x1 at sha deadbee"
names the suspect. Every ``BENCH_*.json`` writer and every metrics
JSONL header stamps ``provenance()`` so ``scripts/check_bench.py`` and
``repro.obs.report --compare`` can report WHAT changed between two
artifacts, not just that something did.
"""
from __future__ import annotations

import os
import platform
import subprocess
import time

import jax

#: provenance keys whose mismatch between a baseline and a fresh run is
#: worth flagging next to a benchmark delta
COMPARE_KEYS = ("jax_version", "backend", "device_count", "git_sha",
                "python")


def git_sha(cwd: str | None = None) -> str:
    """Short HEAD sha of the repo containing this file ("" offline)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
        return out.stdout.strip() if out.returncode == 0 else ""
    except Exception:
        return ""


def provenance() -> dict:
    """Environment fingerprint of the producing process."""
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "git_sha": git_sha(),
        "generated_unix": round(time.time(), 3),
    }


def diff(a: dict | None, b: dict | None) -> list[str]:
    """Human-readable provenance mismatches between two artifacts
    ("jax_version: 0.4.30 -> 0.4.38"); [] when identical or either
    side predates provenance stamping."""
    if not a or not b:
        return []
    return [f"{k}: {a[k]} -> {b[k]}"
            for k in COMPARE_KEYS
            if k in a and k in b and a[k] != b[k]]
