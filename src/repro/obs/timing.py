"""Scoped wall-clock phase timers + jax.profiler hooks.

JAX dispatch is asynchronous: a jitted call returns as soon as the work
is ENQUEUED, so ``time.time()`` around it measures dispatch latency,
not execution — the bug the seed launchers and several benchmarks had.
Every timer here is ``time.perf_counter`` (monotonic, immune to wall
clock steps) and closes its span with ``jax.block_until_ready`` on the
computation's outputs, so a phase's seconds are the seconds the device
actually spent.

``PhaseTimes`` accumulates named phases (staging / compile / scan
dispatch / eval / checkpoint ...) across a run; the execution engine
carries one and the ``MetricsLogger`` serializes its summary. "compile"
is first-call wall time for a given program shape (trace + XLA compile
+ the first execution — the honest definition without AOT plumbing);
steady-state dispatches accumulate under their own phase.

``profile_trace`` / ``annotate`` are the ``--profile <dir>`` hooks:
a ``jax.profiler.trace`` context around the run and named
``TraceAnnotation`` regions around chunks/eval, so the resulting
TensorBoard trace carries the engine's own phase structure.
"""
from __future__ import annotations

import contextlib
import threading
import time

import jax

__all__ = ["PhaseTimes", "sync_time", "profile_trace", "annotate"]


def _block(tree) -> None:
    try:
        jax.block_until_ready(tree)
    except Exception:      # host-only values (floats, History, ...)
        pass


def sync_time(fn, *args, **kwargs):
    """(seconds, result) of ``fn(*args, **kwargs)`` with the span closed
    by ``block_until_ready`` on the result — the one true way to time a
    jitted call."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    _block(out)
    return time.perf_counter() - t0, out


class _Span:
    """Yielded by ``PhaseTimes.phase``; call ``sync(tree)`` with the
    device outputs whose completion closes the span."""

    __slots__ = ("_tree",)

    def __init__(self):
        self._tree = None

    def sync(self, tree):
        self._tree = tree
        return tree


class PhaseTimes:
    """Thread-safe accumulator of named wall-clock phases.

    The staging phase runs on the prefetcher's worker thread while scan
    dispatch runs on the main thread, so accumulation takes a lock;
    phase SPANS of distinct names may overlap (that is the point of
    prefetching — the summary records where time was spent, not a
    partition of the wall)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.seconds: dict[str, float] = {}
        self.calls: dict[str, int] = {}

    def add(self, name: str, seconds: float) -> None:
        with self._lock:
            self.seconds[name] = self.seconds.get(name, 0.0) + seconds
            self.calls[name] = self.calls.get(name, 0) + 1

    @contextlib.contextmanager
    def phase(self, name: str):
        """``with times.phase("eval") as span: span.sync(out)`` — the
        span closes only after the synced outputs are ready."""
        span = _Span()
        t0 = time.perf_counter()
        try:
            yield span
        finally:
            if span._tree is not None:
                _block(span._tree)
            self.add(name, time.perf_counter() - t0)

    def summary(self) -> dict:
        """{phase: {"seconds": s, "calls": n}}, insertion-ordered."""
        with self._lock:
            return {k: {"seconds": round(self.seconds[k], 6),
                        "calls": self.calls[k]}
                    for k in self.seconds}

    def total(self) -> float:
        with self._lock:
            return sum(self.seconds.values())


def profile_trace(outdir: str | None):
    """``jax.profiler.trace`` context for ``--profile <dir>``; a no-op
    context when ``outdir`` is falsy (the flag's default)."""
    if not outdir:
        return contextlib.nullcontext()
    return jax.profiler.trace(outdir)


def annotate(name: str):
    """Named ``TraceAnnotation`` region (shows up in the profiler
    timeline); degrades to a no-op context if the profiler API is
    unavailable in this jax build."""
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()
