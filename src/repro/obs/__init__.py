"""The federation telemetry plane.

The paper's headline claims are OBSERVABILITY claims — training
stability ("up to 93.10%" lower accuracy variance), staleness tolerance
("up to 15 rounds") — so the repo carries a telemetry layer that records
what the AMA mix, the staleness weighting and the environment actually
did each round, without perturbing the run:

  * ``obs.metrics``    — in-scan per-round metric computation (rides the
    fused ``lax.scan`` ys; enabling it never changes params) + the pure
    numpy stability/windowing math shared by ``History`` and the report
    CLI so both reproduce each other exactly;
  * ``obs.log``        — ``MetricsLogger``: schema-versioned JSONL sink
    the execution engine feeds per chunk (``--metrics-out``);
  * ``obs.timing``     — ``PhaseTimes`` scoped wall-clock phases
    (staging / compile / scan dispatch / eval / checkpoint) built on
    ``perf_counter`` + ``block_until_ready`` (async JAX dispatch makes
    naive ``time.time()`` spans fiction), and the ``jax.profiler``
    trace/annotation hooks behind ``--profile``;
  * ``obs.provenance`` — the shared provenance block (jax version,
    backend, device count, git sha) every ``BENCH_*.json`` writer
    stamps, so a benchmark regression reports WHAT regressed;
  * ``obs.report``     — the run-report CLI:
    ``python -m repro.obs.report run.jsonl [--compare other.jsonl]``.
"""
from __future__ import annotations

from repro.obs.log import SCHEMA_VERSION, MetricsLogger
from repro.obs.metrics import (ROUND_METRIC_KEYS, payload_bytes,
                               round_metrics, stability_stats)
from repro.obs.provenance import provenance
from repro.obs.timing import PhaseTimes, annotate, profile_trace, sync_time

__all__ = ["SCHEMA_VERSION", "MetricsLogger", "ROUND_METRIC_KEYS",
           "payload_bytes", "round_metrics", "stability_stats",
           "provenance", "PhaseTimes", "annotate", "profile_trace",
           "sync_time"]
