"""The run-report CLI: Fig. 2/3-style numbers from any metrics JSONL.

  python -m repro.obs.report run.jsonl
  python -m repro.obs.report run.jsonl --last 50
  python -m repro.obs.report --compare a.jsonl b.jsonl

Renders the stability / staleness / participation / mix / throughput
summary of a run recorded with ``--metrics-out`` — no bespoke benchmark
script needed to read the paper's headline quantities off a run. The
accuracy block calls the SAME ``stability_stats`` the engine's
``History`` uses (round-windowed), so ``final_accuracy`` and
``stability_variance`` here reproduce the in-process values exactly.

``--compare`` prints two runs side by side with deltas on the headline
scalars plus any provenance mismatch (jax version, backend, git sha) —
the A/B view for scenario or algorithm sweeps.
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

from repro.obs.provenance import diff as provenance_diff
from repro.obs.log import read_rows, validate_rows
from repro.obs.metrics import stability_stats


def history_from_rows(rows: list[dict]):
    """Rebuild the engine's ``History`` from JSONL rows — the exactness
    bridge between a file on disk and ``SimulationEngine.run``'s
    in-process record."""
    from repro.exec.engine import History
    h = History()
    for r in rows:
        if r.get("kind") == "round":
            h.train_loss.append(float(r["loss"]))
        elif r.get("kind") == "eval":
            h.test_acc.append(float(r["test_acc"]))
            h.test_loss.append(float(r["test_loss"]))
            h.eval_rounds.append(int(r["t"]))
    return h


def _mean(xs):
    return float(np.mean(xs)) if len(xs) else float("nan")


def summarize(rows: list[dict], last: int = 50) -> dict:
    """One flat summary dict per run (everything ``render`` prints)."""
    header = rows[0] if rows and rows[0].get("kind") == "header" else {}
    rnd = [r for r in rows if r.get("kind") == "round"]
    ev = [r for r in rows if r.get("kind") == "eval"]
    phases = [r for r in rows if r.get("kind") == "phases"]
    cfg = header.get("config", {}) or {}
    out = {
        "algorithm": cfg.get("algorithm"), "env": cfg.get("env"),
        "schema": header.get("schema"),
        "provenance": header.get("provenance"),
        "rounds": len(rnd),
        "t_first": rnd[0]["t"] if rnd else None,
        "t_last": rnd[-1]["t"] if rnd else None,
        "train_loss_last": rnd[-1]["loss"] if rnd else None,
    }
    out.update(stability_stats([r["t"] for r in ev],
                               [r["test_acc"] for r in ev], last))
    C = cfg.get("clients_per_round") or None
    if rnd:
        on_time = [r["n_on_time"] for r in rnd]
        out["on_time_mean"] = _mean(on_time)
        if C:
            out["on_time_frac"] = _mean(on_time) / C
            if "n_limited" in rnd[0]:
                out["limited_frac"] = _mean(
                    [r["n_limited"] for r in rnd]) / C
    if rnd and "stale_hist" in rnd[0]:         # extended-metrics series
        hist = np.sum([r["stale_hist"] for r in rnd], axis=0)
        delayed_rows = [r["mean_delay"] for r in rnd
                        if r.get("n_delayed", 0) > 0]
        out.update({
            "stale_hist": hist.astype(int).tolist(),
            "max_staleness_seen": int(np.nonzero(hist)[0].max())
            if hist.any() else 0,
            "mean_delay": _mean(delayed_rows),
            "alpha_eff_first": rnd[0]["alpha_eff"],
            "alpha_eff_last": rnd[-1]["alpha_eff"],
            "delta_norm_mean": _mean([r["delta_norm"] for r in rnd]),
            "update_norm_mean": _mean([r["update_norm"] for r in rnd]),
            "bytes_on_wire_total": float(
                np.sum([r["bytes_on_wire"] for r in rnd])),
        })
    if phases:
        ph = phases[-1]["phases"]              # last segment's summary
        out["phases"] = ph
        train_s = sum(ph[k]["seconds"] for k in
                      ("compile", "scan_dispatch", "round_dispatch")
                      if k in ph)
        if train_s > 0:
            out["rounds_per_sec"] = len(rnd) / train_s
    return out


def _fmt(x, spec=".4f"):
    if x is None or (isinstance(x, float) and np.isnan(x)):
        return "-"
    if isinstance(x, float):
        return format(x, spec)
    return str(x)


def render(s: dict, label: str = "") -> str:
    lines = []
    if label:
        lines.append(f"== {label} ==")
    lines.append(f"run: algorithm={s['algorithm']} env={s['env']} "
                 f"rounds={s['rounds']} (t={s['t_first']}..{s['t_last']}) "
                 f"schema={s['schema']}")
    lines.append(f"accuracy: final={_fmt(s['final_accuracy'])} "
                 f"stability_var={_fmt(s['stability_variance'], '.3f')} "
                 f"(pp^2, {s['n_evals']} evals in round window) "
                 f"train_loss={_fmt(s['train_loss_last'])}")
    if "on_time_frac" in s:
        part = (f"participation: on_time={s['on_time_frac']:.1%}")
        if "limited_frac" in s:
            part += f" limited={s['limited_frac']:.1%}"
        lines.append(part)
    if "stale_hist" in s:
        lines.append(f"staleness: hist={s['stale_hist']} "
                     f"max_seen={s['max_staleness_seen']} "
                     f"mean_delay={_fmt(s['mean_delay'], '.2f')}")
        lines.append(f"mix: alpha_eff {_fmt(s['alpha_eff_first'])} -> "
                     f"{_fmt(s['alpha_eff_last'])}   "
                     f"|delta|={_fmt(s['delta_norm_mean'], '.3f')} "
                     f"|update|={_fmt(s['update_norm_mean'], '.3f')}")
        lines.append(f"wire: {s['bytes_on_wire_total'] / 1e6:.2f} MB "
                     f"uploaded on time "
                     f"({s['bytes_on_wire_total'] / 1e6 / max(s['rounds'], 1):.3f} MB/round)")
    if "phases" in s:
        total = sum(v["seconds"] for v in s["phases"].values()) or 1.0
        breakdown = "  ".join(
            f"{k}={v['seconds']:.2f}s({v['seconds'] / total:.0%})"
            for k, v in s["phases"].items())
        tput = (f" | {s['rounds_per_sec']:.2f} rounds/s"
                if "rounds_per_sec" in s else "")
        lines.append(f"phases: {breakdown}{tput}")
    return "\n".join(lines)


#: headline scalars --compare prints deltas for
DELTA_KEYS = ("final_accuracy", "stability_variance", "on_time_frac",
              "mean_delay", "alpha_eff_last", "bytes_on_wire_total",
              "rounds_per_sec")


def compare(sa: dict, sb: dict) -> str:
    lines = [render(sa, "A"), "", render(sb, "B"), "", "-- deltas (B - A) --"]
    for k in DELTA_KEYS:
        if isinstance(sa.get(k), (int, float)) and isinstance(
                sb.get(k), (int, float)):
            lines.append(f"{k}: {sa[k]:.4f} -> {sb[k]:.4f} "
                         f"({sb[k] - sa[k]:+.4f})")
    pd = provenance_diff(sa.get("provenance"), sb.get("provenance"))
    if pd:
        lines.append("provenance mismatch: " + "; ".join(pd))
    return "\n".join(lines)


def _load(path: str) -> list[dict]:
    rows = read_rows(path)
    errs = validate_rows(rows)
    if errs:
        for e in errs:
            print(f"{path}: SCHEMA ERROR: {e}", file=sys.stderr)
        raise SystemExit(2)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a --metrics-out JSONL run record.")
    ap.add_argument("jsonl", nargs="?", help="metrics JSONL to report on")
    ap.add_argument("--last", type=int, default=50,
                    help="stability window in ROUNDS (paper: 50)")
    ap.add_argument("--compare", nargs=2, metavar=("A", "B"),
                    help="A/B summary of two runs with deltas")
    args = ap.parse_args(argv)
    if args.compare:
        a, b = (summarize(_load(p), args.last) for p in args.compare)
        print(compare(a, b))
        return 0
    if not args.jsonl:
        ap.error("need a JSONL path (or --compare A B)")
    print(render(summarize(_load(args.jsonl), args.last)))
    return 0


if __name__ == "__main__":
    try:
        code = main()
    except BrokenPipeError:       # `... | head` closed the pipe: fine
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        code = 0
    raise SystemExit(code)
