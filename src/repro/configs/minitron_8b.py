"""Minitron-8B — pruned Nemotron-4 [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Dense full attention; long_500k runs via the beyond-paper SWA serving
variant (window 4096) — see DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=16384,
    vocab_size=256000,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    train_fsdp=True,
    source="arXiv:2407.14679",
)

# beyond-paper long-context serving variant (sliding window)
CONFIG_SWA = CONFIG.with_(sliding_window=4096)
