"""Phi-3.5-MoE 42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400, 16 experts top-2, vocab=32064.
16 experts == model-axis size -> pure expert-parallel sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab_size=32064,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    num_experts=16,
    top_k=2,
    moe_group_size=4096,   # blocked dispatch (§Perf H1)
    train_fsdp=True,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
