"""Mixtral 8x22B — 8 experts top-2, sliding-window attn [arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
SWA window 4096 -> native long_500k path (ring-buffer KV cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    d_ff=16384,
    vocab_size=32768,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    num_experts=8,
    top_k=2,
    sliding_window=4096,
    moe_group_size=4096,   # blocked dispatch (§Perf H1)
    train_fsdp=True,
    serve_2d=True,
    source="arXiv:2401.04088",
)
