"""Config dataclasses for models, input shapes and federated runs."""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per ``configs/<arch>.py``."""

    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio | cnn
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    num_heads: int = 0          # 0 for attention-free families
    num_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_group_size: int = 0     # >0: blocked dispatch over token groups —
                                # one-hot dispatch FLOPs become linear in T
                                # instead of quadratic (see EXPERIMENTS §Perf)
    # --- attention details ---
    sliding_window: int = 0     # 0 = full attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mlp_gated: bool = True      # SwiGLU vs plain GELU MLP
    # --- SSM / linear attention ---
    ssm_state: int = 0          # mamba2 state size
    conv_width: int = 4
    # --- hybrid (zamba2-style) ---
    attn_every: int = 0         # insert a (shared) attention block every N blocks
    shared_attn: bool = False   # one shared attention param set (Zamba2)
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0        # precomputed frame embeddings length
    # --- VLM ---
    num_patches: int = 0        # precomputed patch embeddings length
    vision_dim: int = 0         # stub frontend output dim (projected to d_model)
    # --- numerics / sharding ---
    dtype: str = "bfloat16"
    train_fsdp: bool = False    # shard params over the dsub axis during training
    serve_2d: bool = False      # 2-D tensor parallel at serving time (very large)
    remat: bool = True
    unroll_chunks: bool = False # unroll attention KV-chunk loop (dry-run: makes
                                # cost_analysis see every chunk; scans are
                                # otherwise costed once by HloCostAnalysis)
    unroll_layers: bool = False # unroll the layer scan (roofline calibration
                                # lowerings at reduced depth)
    shard_residuals: bool = False  # store the per-layer activation
                                # checkpoints model-sharded (d on "model"):
                                # 16x smaller residual stack for one extra
                                # all-gather per layer in backward (§Perf H3)
    attn_chunk: int = 512       # KV chunk for online-softmax attention
    # --- FES split (paper Eq. 2): classifier = final norm + head + tail blocks
    fes_tail_layers: int = 2
    # --- provenance ---
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning runtime config (paper Table I defaults)."""

    num_clients: int = 50          # K
    clients_per_round: int = 10    # m
    rounds: int = 200              # B
    local_epochs: int = 10         # e
    local_batch_size: int = 32
    lr: float = 0.001              # epsilon
    # AMA (paper: alpha0=0.1, eta=2.5e-3, b=0.6)
    alpha0: float = 0.1
    eta: float = 2.5e-3
    staleness_b: float = 0.6
    alpha_cap: float = 0.95        # keep beta > 0 for long runs
    # heterogeneity simulation
    p_limited: float = 0.25        # ratio of computing-limited devices
    p_delay: float = 0.0           # prob. of transmission delay (0.3 / 0.7)
    max_delay: int = 0             # 5 / 10 / 15 rounds; 0 disables async path
    # environment name (see repro.env registry):
    # "bernoulli" | "gilbert_elliott" | "bandwidth" | "trace"
    env: str = "bernoulli"
    # gilbert_elliott: two-state Markov fading channel
    ge_p_gb: float = 0.15          # Good -> Bad transition prob per round
    ge_p_bg: float = 0.45          # Bad -> Good
    ge_p_delay_good: float = 0.05  # delay prob on a Good link
    ge_p_delay_bad: float = 0.9    # delay prob on a Bad link
    # bandwidth: log-normal uplink rate vs a round deadline
    bw_upload_mbits: float = 4.0   # model-update upload size (megabits)
    bw_mean_mbps: float = 2.0      # median uplink rate
    bw_sigma: float = 0.8          # log-std (shadow fading)
    bw_deadline_s: float = 1.0     # round deadline (seconds)
    # trace: .npz replay path ("" -> synthetic mobility trace)
    trace_path: str = ""
    # population realisation (repro.env.virtual): "auto" keeps the dense
    # bit-identical paper path up to VIRTUAL_K_MIN clients and switches
    # to the K-free hashed VirtualPopulation machinery above it;
    # "dense"/"virtual" force either at any K
    population: str = "auto"
    # staging look-ahead: how many chunks ChunkPrefetcher keeps in
    # flight ahead of the device (host memory ~ depth x chunk bytes)
    prefetch_depth: int = 1
    # pre-reduce the stacked (C, N) client plane to the (N,) weighted
    # sums the server planes actually consume BEFORE the server update,
    # so the cross-device collective moves N, not C x N, bytes:
    #   "auto"  — on when the active mesh's client axis is > 1
    #   "off"   — always the stacked fused path
    #   "force" — always reduce (CPU equivalence tests)
    client_reduce: str = "auto"
    # server strategy name (see repro.core.strategies registry):
    # "ama" (alias "ama_fes") | "async_ama" | "fedavg" | "fedprox" | "fedopt"
    algorithm: str = "ama_fes"
    fedprox_rho: float = 0.01
    fedprox_partial: float = 0.5   # fraction of local steps on limited devices
    # fedopt (server-side Adam on the aggregated pseudo-gradient)
    server_lr: float = 0.1
    server_b1: float = 0.9
    server_b2: float = 0.99
    server_tau: float = 1e-3
    # route every strategy's mix step through the fused Pallas ama_mix
    # kernel (interpret-mode off-TPU; see repro.kernels.ops). Applies to
    # the LEGACY aggregate() path only; the round engine dispatches the
    # fused server plane below.
    use_kernel: bool = False
    # the server-plane implementation the round engine dispatches
    # (core.round.make_round_step -> ServerStrategy.fused_server_update):
    #   "fused"     — one fused pass per round (weights, delta
    #                 accumulation, ring-buffer mix, server-Adam in a
    #                 single HBM pass): pallas_call on TPU, the jitted
    #                 flat oracle off-TPU
    #   "ref"       — always the flat jnp oracle (kernels/ref.py)
    #   "interpret" — the Pallas kernel through the interpreter
    #                 (kernel-body validation; slow, tests only)
    #   "legacy"    — the original per-leaf aggregate() chain
    server_plane: str = "fused"
    # compressed client->server uplink (repro.comm registry):
    #   "none" — dense full-precision deltas (bit-identical legacy path)
    #   "bf16" — deltas cast to bfloat16 (2x, exact error feedback)
    #   "q8"   — stochastic-rounded int8 + per-cohort scale (~4x)
    #   "topk" — top-k magnitude sparsification ((value, index) pairs)
    # The bandwidth environment's deadline check and the extended
    # metrics' bytes_on_wire_compressed consume the ACTUAL compressed
    # payload size, so delay tolerance becomes a function of the plane.
    comm_plane: str = "none"
    comm_topk_frac: float = 0.01   # topk: surviving fraction per dtype group
    comm_error_feedback: bool = True  # carry the EF residual (aux["comm"])
    # the client-plane execution mode for MIXED (limited x unlimited)
    # cohorts (core.round.make_round_step; ``fes_static`` below is the
    # third, all-limited mode):
    #   "masked"      — ONE program for every cohort; limited cohorts
    #                   compute the full body backward and mask it (the
    #                   bit-identity reference under the chunked scan)
    #   "partitioned" — group each round's cohorts by limited-ness at
    #                   the staging layer and dispatch two vmapped
    #                   programs: the masked program for the unlimited
    #                   group and a classifier-only / statically
    #                   truncated program for the limited group (the
    #                   body backward is never traced — the paper's
    #                   Eq. 3 computation reduction for real)
    client_plane: str = "masked"
    fes_static: bool = False       # ALL cohorts computing-limited: classifier-
                                   # only differentiation (the body backward is
                                   # never built — paper §III at pod scale)
    fes_enabled: bool = True
    # telemetry plane (repro.obs): emit the extended per-round metric
    # series (staleness histogram, participation counts, effective mix
    # coefficient, delta/update norms, bytes-on-wire) as extra scan ys.
    # Opt-in; enabling it never changes the params stream (bit-identity
    # gated in tests/test_obs.py). The launcher switches it on with
    # --metrics-out.
    extended_metrics: bool = False
    seed: int = 0
    # pod-scale runs: #parallel client cohorts simulated in one jitted round
    cohorts: int = 4
    local_steps: int = 1           # grad steps per cohort per round (pod-scale)

    def with_(self, **kw) -> "FLConfig":
        return replace(self, **kw)


def reduced(cfg: ModelConfig, **kw) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    small = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 256),
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        train_fsdp=False,
        serve_2d=False,
    )
    if cfg.num_heads:
        small["num_heads"] = min(cfg.num_heads, 4)
        small["num_kv_heads"] = max(1, min(cfg.num_kv_heads, 2))
        small["head_dim"] = 64
    if cfg.num_experts:
        small["num_experts"] = min(cfg.num_experts, 4)
    if cfg.ssm_state:
        small["ssm_state"] = min(cfg.ssm_state, 16)
    if cfg.encoder_layers:
        small["encoder_layers"] = 2
        small["encoder_seq"] = min(cfg.encoder_seq, 64)
    if cfg.num_patches:
        small["num_patches"] = min(cfg.num_patches, 16)
        small["vision_dim"] = min(cfg.vision_dim or cfg.d_model, 128)
    if cfg.sliding_window:
        small["sliding_window"] = min(cfg.sliding_window, 64)
    if cfg.attn_every:
        small["attn_every"] = 2
    small["fes_tail_layers"] = 1
    small.update(kw)
    return cfg.with_(**small)
