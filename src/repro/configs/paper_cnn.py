"""The paper's own evaluation model: 2 conv (5x5) + 3 FC, 10 classes.

Used for the paper-faithful AMA-FES experiments (Fig. 2 / Fig. 3 scale:
K=50 clients, m=10/round, MNIST/FMNIST-shaped 28x28x1 inputs).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-cnn",
    family="cnn",
    num_layers=5,
    d_model=320,
    d_ff=120,
    vocab_size=10,          # n_classes
    dtype="float32",
    remat=False,
    source="paper §V (LeNet-style)",
)
