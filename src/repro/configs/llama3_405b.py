"""Llama-3.1 405B [arXiv:2407.21783].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
Largest assigned arch: FSDP + 2-D tensor parallel mandatory.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    d_ff=53248,
    vocab_size=128256,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=500_000.0,
    train_fsdp=True,
    serve_2d=True,
    source="arXiv:2407.21783",
)
