"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (kv=32, MHA) d_ff=8192 vocab=32064.
Vision frontend (CLIP ViT) is a STUB per the assignment: input_specs hands
the decoder precomputed patch embeddings (projected in-model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab_size=32064,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    num_patches=576,        # 24x24 CLIP-L/14 grid @336px
    vision_dim=1024,        # CLIP ViT-L hidden size
    train_fsdp=True,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
