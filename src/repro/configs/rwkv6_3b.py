"""RWKV-6 "Finch" 3B — attention-free, data-dependent decay [arXiv:2404.05892].

32L d_model=2560 (40 heads x 64), d_ff=8960, vocab=65536.
Sub-quadratic (O(1) state) -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65536,
    train_fsdp=True,
    fes_tail_layers=2,
    source="arXiv:2404.05892",
)
