"""Whisper-medium [arXiv:2212.04356] — encoder-decoder, conv frontend stub.

24L (encoder) + 24L (decoder), d_model=1024, 16H MHA, d_ff=4096, vocab=51865.
mel+conv codec is a STUB: input_specs hands 1500 precomputed frame embeddings.
Plain (non-gated) GELU MLP as in the original.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,          # decoder depth
    encoder_layers=24,
    encoder_seq=1500,       # 30 s of audio at 50 Hz after conv stride
    d_model=1024,
    d_ff=4096,
    vocab_size=51865,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    mlp_gated=False,
    source="arXiv:2212.04356",
)
