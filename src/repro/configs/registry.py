"""Architecture registry: --arch <id> -> ModelConfig.

Also the config-side door to the environment/scenario registries
(``repro.env``): ``get_scenario`` / ``scenario_names`` resolve a named
experimental condition to FLConfig knobs (lazy imports — repro.env
imports configs.base, so the env package must not be imported at this
module's import time)."""
from __future__ import annotations

from repro.configs import (llama3_405b, minitron_8b, mistral_large_123b,
                           mixtral_8x22b, paper_cnn, phi3_vision_4b,
                           phi35_moe_42b, qwen15_110b, rwkv6_3b,
                           whisper_medium, zamba2_1b)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

ARCHS: dict[str, ModelConfig] = {
    "rwkv6-3b": rwkv6_3b.CONFIG,
    "minitron-8b": minitron_8b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b.CONFIG,
    "mistral-large-123b": mistral_large_123b.CONFIG,
    "mixtral-8x22b": mixtral_8x22b.CONFIG,
    "llama3-405b": llama3_405b.CONFIG,
    "phi-3-vision-4.2b": phi3_vision_4b.CONFIG,
    "whisper-medium": whisper_medium.CONFIG,
    "zamba2-1.2b": zamba2_1b.CONFIG,
    "qwen1.5-110b": qwen15_110b.CONFIG,
    "paper-cnn": paper_cnn.CONFIG,
}

ASSIGNED = [k for k in ARCHS if k != "paper-cnn"]

# long_500k applicability (sub-quadratic rule; see DESIGN.md §Arch-applicability)
LONG_CONTEXT_OK = {
    "rwkv6-3b": True,            # O(1) recurrent state
    "zamba2-1.2b": True,         # O(1) SSM state + windowed shared attn
    "mixtral-8x22b": True,       # native SWA ring cache
    "minitron-8b": True,         # beyond-paper SWA serving variant
    "phi3.5-moe-42b-a6.6b": False,
    "mistral-large-123b": False,
    "llama3-405b": False,
    "phi-3-vision-4.2b": False,
    "whisper-medium": False,     # enc-dec over 30-s audio
    "qwen1.5-110b": False,
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def get_scenario(name: str):
    """Named scenario -> Scenario (see repro.env.scenarios)."""
    from repro.env import scenarios
    return scenarios.get(name)


def scenario_names() -> list[str]:
    from repro.env import scenarios
    return scenarios.names()


def environment_names() -> list[str]:
    from repro import env
    return env.names()


def serving_config(name: str) -> ModelConfig:
    """Config used for decode shapes (long-context variants where needed)."""
    cfg = get_arch(name)
    if name == "minitron-8b":
        return minitron_8b.CONFIG_SWA
    return cfg


def pairs():
    """All assigned (arch, shape) combos that must lower (40 total; skips
    are recorded, not silently dropped)."""
    out = []
    for a in ASSIGNED:
        for s in SHAPES.values():
            skip = s.name == "long_500k" and not LONG_CONTEXT_OK[a]
            out.append((a, s.name, skip))
    return out
