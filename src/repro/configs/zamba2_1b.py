"""Zamba2-1.2B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

38 Mamba2 blocks, d_model=2048, shared attn (32H MHA) every 6 blocks,
d_ff=8192, vocab=32000, ssm_state=64. Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    ssm_state=64,
    attn_every=6,
    shared_attn=True,
    source="arXiv:2411.15242",
)
