"""Jitted, batched, model-generic evaluation (the engine's eval layer).

Replaces the unjitted CNN-hardcoded full-test-set ``evaluate``: one
compiled program scans fixed-size test batches and accumulates exact
per-example sums (correct predictions, negative log-likelihood, count),
so accuracy/loss are independent of the batch split and a single device
dispatch per eval. Works for any model whose ``forward`` returns
``(logits, aux)`` with labels of shape ``logits.shape[:-1]`` — the
paper CNN's (B, classes) and token-level (B, S, V) heads alike.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.timing import annotate


def make_eval_step(model):
    """Returns jitted eval(params, batches, mask) -> (correct, nll, n).

    batches: pytree with leading (n_batches, batch, ...) axes;
    mask: (n_batches, batch) — 0 for padding examples. The whole test
    set is consumed by ONE ``lax.scan`` dispatch; sums come back exact.
    """

    def eval_batch(params, batch, mask):
        logits, _ = model.forward(params, batch)
        labels = batch["label"]
        lf = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(lf, axis=-1)
        iota = jax.lax.broadcasted_iota(labels.dtype, lf.shape, lf.ndim - 1)
        gold = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), -1)
        nll = logz - gold
        hit = (jnp.argmax(lf, -1) == labels).astype(jnp.float32)
        m = jnp.broadcast_to(
            mask.reshape(mask.shape + (1,) * (labels.ndim - mask.ndim)),
            labels.shape).astype(jnp.float32)
        return jnp.sum(hit * m), jnp.sum(nll * m), jnp.sum(m)

    def eval_all(params, batches, mask):
        def body(acc, xs):
            b, m = xs
            c, l, n = eval_batch(params, b, m)
            return (acc[0] + c, acc[1] + l, acc[2] + n), None

        zero = jnp.float32(0.0)
        (c, l, n), _ = jax.lax.scan(body, (zero, zero, zero),
                                    (batches, mask))
        return c, l, n

    return jax.jit(eval_all)


class Evaluator:
    """Pads + batches a test set once, then evaluates params repeatedly.

    ``__call__(params) -> (accuracy, mean_loss)`` — exact means over the
    original (unpadded) examples, shared by the paper-scale simulation
    and the pod path alike.
    """

    def __init__(self, model, test_data: dict, batch_size: int = 512):
        n = len(next(iter(test_data.values())))
        bs = min(batch_size, n)
        nb = int(np.ceil(n / bs))
        idx = np.arange(nb * bs) % n          # wrap-pad; padding is masked
        self._batches = {
            k: jnp.asarray(np.asarray(v)[idx].reshape((nb, bs)
                                                      + v.shape[1:]))
            for k, v in test_data.items()}
        self._mask = jnp.asarray(
            (np.arange(nb * bs) < n).reshape(nb, bs), jnp.float32)
        self._fn = make_eval_step(model)

    def __call__(self, params) -> tuple[float, float]:
        # a named region so --profile traces show eval as one block
        # (the engine's PhaseTimes books the wall time; the float()
        # conversions below are the synchronization point)
        with annotate("evaluator"):
            c, l, n = self._fn(params, self._batches, self._mask)
            n = float(n)
            return float(c) / n, float(l) / n
