"""The unified chunked-scan execution engine.

One engine, two configurations — the paper-scale §V simulation
(K simulated clients, real per-client data staged per round) and the
pod-scale cohort run (C cohorts over the FL mesh) are the SAME round
path with a different data plane:

  * ``ChunkRunner`` drives rounds in chunks through the fused
    ``core.round.make_train_loop`` scan (donated carry, one XLA dispatch
    per chunk), with a ``use_scan=False`` per-round-jit fallback that is
    bit-identical (the ``--no-scan`` safety net — see
    tests/test_engine.py);
  * ``SimulationEngine`` adds the vectorized data plane
    (``data.pipeline.stage_chunk`` — one fancy-gather per chunk of
    rounds, next chunk prefetched host-side while the current chunk runs
    on device), the jitted batched eval (``exec.evals.Evaluator``) at an
    ``eval_every`` cadence, full round-state checkpointing
    ({params, t, aux}: async ring buffer, fedopt moments, ...) and the
    ``History`` stability metrics;
  * both run under the FL mesh (``launch.mesh.engine_mesh``) so the
    stacked client axis of params and batches is sharded on a pod and a
    degenerate no-op on this CPU container — the identical program at
    both scales.

Everything round-path-schedulable comes in through the two registries:
the server rule is a ``ServerStrategy``, the world an ``Environment``;
the engine owns only data movement, chunking and evaluation. The server
side of every round — staleness weights, weighted delta accumulation,
ring-buffer mix, server-Adam — dispatches as ONE fused server-plane
kernel call (``ServerStrategy.fused_server_update`` →
``repro.kernels.server_plane``) on both the chunked-scan path and the
``--no-scan`` per-round path; ``fl.server_plane`` selects the impl
("fused" | "ref" | "legacy").
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import env as env_mod
from repro.checkpoint.io import restore_state, save_state
from repro.configs.base import FLConfig
from repro.core import strategies
from repro.core.round import as_scan_scheds, init_state, make_train_loop
from repro.data.pipeline import ChunkPrefetcher, partition_plan, stage_chunk
from repro.exec.evals import Evaluator
from repro.obs.metrics import stability_stats
from repro.obs.timing import PhaseTimes, annotate


@dataclass
class History:
    """Per-run metric record. ``test_acc[i]`` was measured after
    ``eval_rounds[i]`` rounds (ABSOLUTE indices — a resumed run
    continues the count), so the stability window is a span of ROUNDS
    regardless of the eval cadence: with ``eval_every=5``,
    ``stability_variance(last=50)`` covers the 10 eval points of the
    last 50 rounds, not 50 eval points spanning 250 rounds (the seed's
    silent unit confusion). The round-window math lives in
    ``repro.obs.metrics.stability_stats`` — the report CLI calls the
    same function on a metrics JSONL, which is why the two always
    agree exactly."""

    test_acc: list = field(default_factory=list)
    test_loss: list = field(default_factory=list)
    train_loss: list = field(default_factory=list)
    eval_rounds: list = field(default_factory=list)

    def stability_variance(self, last: int = 50) -> float:
        """Paper's stability metric: variance of test accuracy over the
        last ``last`` ROUNDS (in percentage points squared)."""
        return stability_stats(self.eval_rounds, self.test_acc,
                               last)["stability_variance"]

    def final_accuracy(self, last: int = 50) -> float:
        return stability_stats(self.eval_rounds, self.test_acc,
                               last)["final_accuracy"]


class ChunkRunner:
    """The unified round path: N rounds per call, fused scan or fallback.

    ``per_round_batch=True`` (paper scale) scans a fresh
    (n, C, steps, b, ...) batch row per round; ``False`` (pod scale)
    re-feeds one (C, steps, b, ...) batch every round. ``use_scan=False``
    replays the identical rounds one at a time (scan of length 1) — the
    bit-identical ``--no-scan`` configuration. A mesh makes the
    engine span a pod: the call runs under it, activating the
    stacked-client-axis constraints inside ``make_round_step``.
    """

    def __init__(self, model, fl: FLConfig, strategy=None, *,
                 per_round_batch: bool = True, use_scan: bool = True,
                 mesh=None, donate: bool = True, timer=None):
        self.model, self.fl = model, fl
        self.strategy = strategy or strategies.resolve(fl)
        self.per_round_batch = per_round_batch
        self.use_scan = use_scan
        self.mesh = mesh
        # ONE jitted train_loop serves the fused chunk scan AND the
        # per-round fallback (scan of length 1): jax.jit specialises per
        # chunk-length shape under the same callable, and sharing the
        # callable keeps the two paths structurally identical
        self._loop = None
        self._donate = donate
        # telemetry: phase wall-clock (repro.obs.timing.PhaseTimes).
        # The first dispatch of a given chunk length is a fresh jit
        # specialisation, so its wall time books under "compile"
        # (trace + XLA compile + first execution); steady-state chunks
        # book under "scan_dispatch" / "round_dispatch"
        self.timer = timer if timer is not None else PhaseTimes()
        self._compiled: set = set()

    def _dispatch(self, loop, state, batch, scheds, n: int, *,
                  scan: bool):
        key = (n, self.per_round_batch)
        phase = ("compile" if key not in self._compiled
                 else ("scan_dispatch" if scan and n > 1
                       else "round_dispatch"))
        self._compiled.add(key)
        with self.timer.phase(phase) as span, \
                annotate(f"train_chunk_n{n}"):
            if getattr(self.fl, "extended_metrics", False):
                # extended telemetry: the loop takes a shadow tap — a
                # device COPY of the entering {params, aux} (separate
                # buffers keep donation usable and keep XLA from
                # value-numbering the tap onto the live carry; see
                # make_train_loop). The copy is O(model), once per
                # dispatch — noise next to the chunk's training work.
                tap0 = jax.tree.map(jnp.copy, {"params": state["params"],
                                               "aux": state["aux"]})
                out = loop(state, batch, scheds, tap0)
            else:
                out = loop(state, batch, scheds)
            span.sync(out)
        return out

    def _ctx(self):
        return self.mesh if self.mesh is not None else (
            contextlib.nullcontext())

    def _train_loop(self):
        if self._loop is None:
            self._loop = make_train_loop(
                self.model, self.fl, self.strategy,
                per_round_batch=self.per_round_batch, donate=self._donate)
        return self._loop

    def run_chunk(self, state, batch, sched_batch: dict, *,
                  scan_ok: bool = True):
        """(state, batch, Environment.batch dict) -> (state, metrics).

        ``batch`` leaves: (n, C, steps, b, ...) when per_round_batch
        else (C, steps, b, ...); numpy or device arrays. ``metrics``
        come back as numpy arrays with a leading (n,) axis.
        ``scan_ok=False`` routes an off-cadence chunk (a tail shorter
        than ``eval_every``, a standalone single round) through the
        bit-identical per-round path instead of compiling a fresh
        scan program for its one-off length. That path is a SCAN OF
        LENGTH 1 per round, not a bare jitted round step: XLA compiles
        a ``lax.scan`` body as its own computation, so the per-round
        program and the chunked scan contract multiply-add chains
        identically — a bare per-round jit re-fuses the fused
        server-plane chains with the surrounding round and drifts by
        1-2 ulp, which the bit-identity nets (and resume across chunk
        boundaries) do not tolerate.
        """
        if (getattr(self.fl, "client_plane", "masked") == "partitioned"
                and not self.fl.fes_static
                and "part_src_row" not in sched_batch):
            # partitioned client plane: group the chunk's cohorts by
            # limited-ness host-side (the staging layer's other half);
            # the plan is chunk-level so the fused scan and the
            # per-round fallback replay the IDENTICAL dispatch
            sched_batch = {**sched_batch,
                           **partition_plan(sched_batch["limited"])}
        scheds = as_scan_scheds(sched_batch)
        n = int(jax.tree.leaves(scheds)[0].shape[0])
        batch = jax.tree.map(jnp.asarray, batch)
        with self._ctx():
            loop = self._train_loop()
            if self.use_scan and scan_ok:
                state, metrics = self._dispatch(loop, state, batch,
                                                scheds, n, scan=True)
            else:
                rows = []
                for r in range(n):
                    b = (jax.tree.map(lambda x: x[r:r + 1], batch)
                         if self.per_round_batch else batch)
                    sc = jax.tree.map(lambda x: x[r:r + 1], scheds)
                    state, m = self._dispatch(loop, state, b, sc, 1,
                                              scan=False)
                    rows.append(jax.tree.map(lambda x: x[0], m))
                metrics = {k: jnp.stack([m[k] for m in rows])
                           for k in rows[0]}
        return state, jax.tree.map(np.asarray, metrics)


class SimulationEngine:
    """Paper-scale federated simulation on the chunked-scan engine.

    Drives ``eval_every``-round chunks through ``ChunkRunner`` over any
    registered environment: schedules from ``Environment.batch``, client
    batches staged in one gather per chunk (``stage_chunk``) with the
    next chunk prefetched host-side, eval through the jitted batched
    ``Evaluator``. ``use_scan=False`` is the per-round fallback
    (bit-identical; the refactor's safety net).
    """

    def __init__(self, model, fl: FLConfig, clients, test_data,
                 eval_fn=None, eval_batch: int = 512, environment=None,
                 use_scan: bool = True, mesh=None, prefetch: bool = True,
                 donate: bool = True, logger=None):
        self.model = model
        self.fl = fl
        # clients: a dense list[ClientDataset] OR a VirtualClientShards
        # (K-free streamed staging — client shards are arithmetic views
        # of one base store, nothing materialised per client)
        self.clients = clients
        self._streamed = hasattr(clients, "shard_indices")
        self.test_data = test_data
        # any registered environment (fl.env); data sizes feed the
        # |D_i| aggregation weights through the schedule contract —
        # as a dense (K,) vector for a client list, as a callable for
        # virtual shards (a (K,) vector is what we are avoiding)
        self.env = environment or env_mod.resolve(
            fl, data_sizes=(clients.client_sizes if self._streamed else
                            np.array([len(c) for c in clients],
                                     np.float32)))
        self.strategy = strategies.resolve(fl)
        # donate=True updates the carry in place on accelerator backends,
        # which also invalidates params references held from BEFORE a
        # run() call; pass False to keep pre-run references alive there
        self.runner = ChunkRunner(model, fl, self.strategy,
                                  per_round_batch=True, use_scan=use_scan,
                                  mesh=mesh, donate=donate)
        self._eval_fn = eval_fn
        self._evaluator = (None if eval_fn is not None
                           else Evaluator(model, test_data, eval_batch))
        self.prefetch = prefetch
        # telemetry plane: one PhaseTimes spans runner + data plane +
        # eval + checkpointing; an optional MetricsLogger (repro.obs.log)
        # receives per-round rows, eval points and the phase summary
        self.timer = PhaseTimes()
        self.runner.timer = self.timer
        self.logger = logger
        self.data = clients.data if self._streamed else clients[0].data
        if not self._streamed and any(c.data is not self.data
                                      for c in clients):
            raise ValueError(
                "the chunked data plane stages every client from ONE "
                "shared sample store (build clients with "
                "data.pipeline.build_clients(data, partition))")
        self.state = init_state(model, fl, jax.random.PRNGKey(fl.seed),
                                self.strategy)

    # engine state — the full round carry {params, t, aux} ---------------
    @property
    def params(self):
        return self.state["params"]

    @property
    def t(self) -> int:
        return int(self.state["t"])

    @property
    def aux(self):
        return self.state["aux"]

    def save(self, path: str) -> None:
        """Checkpoint the WHOLE round state (params, round index, aux:
        async ring buffer, fedopt moments, ...)."""
        with self.timer.phase("checkpoint"):
            save_state(path, self.state)

    def resume(self, path: str) -> None:
        """Bit-identical continuation: restore {params, t, aux}; staging
        and schedules are pure in t, so the next chunk starts exactly
        where the checkpointed run left off."""
        self.state = restore_state(path, self.state)

    # ------------------------------------------------------------------
    def _steps_per_round(self) -> int:
        n_min = (self.clients.min_size if self._streamed
                 else min(len(c) for c in self.clients))
        per_epoch = max(1, n_min // self.fl.local_batch_size)
        return self.fl.local_epochs * per_epoch

    def _stage(self, t0: int, n: int):
        # runs on the prefetcher's worker thread during overlapped
        # execution — PhaseTimes is thread-safe, so "stage" seconds
        # accumulate either way (they OVERLAP device phases by design)
        with self.timer.phase("stage"), annotate(f"stage_t{t0}"):
            sb = self.env.batch(t0, n)
            batch = stage_chunk(self.data, self.clients, sb["selected"],
                                self.fl.seed, t0,
                                self._steps_per_round(),
                                self.fl.local_batch_size)
        return sb, batch

    def run_round(self) -> float:
        """One round through the engine (a chunk of 1; per-round step —
        no one-off scan program for a standalone round)."""
        sb, batch = self._stage(self.t, 1)
        self.state, metrics = self.runner.run_chunk(self.state, batch, sb,
                                                    scan_ok=False)
        return float(metrics["loss"][0])

    def evaluate(self) -> tuple[float, float]:
        with self.timer.phase("eval"), annotate("eval"):
            if self._eval_fn is not None:
                return self._eval_fn(self.state["params"],
                                     self.test_data)
            return self._evaluator(self.state["params"])

    def run(self, rounds: int | None = None, eval_every: int = 1,
            verbose: bool = False) -> History:
        hist = History()
        rounds = rounds or self.fl.rounds
        t0, end = self.t, self.t + rounds
        if self.logger is not None:
            from repro.obs.metrics import payload_bytes
            self.logger.header(self.fl,
                               payload=payload_bytes(self.params),
                               resumed_at=t0 if t0 else None)
        # chunk boundaries sit on ABSOLUTE multiples of eval_every, so a
        # resumed run evaluates at the same global rounds as the
        # uninterrupted run it continues (off-cadence head/tail chunks
        # replay through the per-round step, no one-off scan compile)
        chunks, t = [], t0
        while t < end:
            n = min((t // eval_every + 1) * eval_every, end) - t
            chunks.append((t, n))
            t += n
        staged = (ChunkPrefetcher(lambda c: self._stage(*c), chunks,
                                  depth=getattr(self.fl, "prefetch_depth",
                                                1))
                  if self.prefetch else (self._stage(*c) for c in chunks))
        try:
            for (t, n), (sb, batch) in zip(chunks, staged):
                self.state, metrics = self.runner.run_chunk(
                    self.state, batch, sb, scan_ok=(n == eval_every))
                hist.train_loss.extend(float(x) for x in metrics["loss"])
                if self.logger is not None:
                    self.logger.rounds(t, metrics)
                if (t + n) % eval_every == 0:    # partial chunks: no eval
                    acc, loss = self.evaluate()
                    hist.test_acc.append(acc)
                    hist.test_loss.append(loss)
                    hist.eval_rounds.append(t + n)
                    if self.logger is not None:
                        self.logger.eval(t + n, acc, loss)
                    done = t + n - t0
                    if verbose and done % 10 == 0:
                        print(f"  round {done:4d} "
                              f"train_loss={hist.train_loss[-1]:.4f} "
                              f"test_acc={acc:.4f}")
        finally:
            if isinstance(staged, ChunkPrefetcher):
                staged.close()           # abandoned mid-run: release the
            if self.logger is not None:  # worker + buffered chunks
                self.logger.phases(self.timer)
        return hist
