"""Unified chunked-scan execution engine: one sharded data plane +
round path serving both the paper-scale simulation and the pod scale.

``ChunkRunner`` is the round path (fused scan per chunk, per-round
fallback); ``SimulationEngine`` the paper-scale configuration on top of
it; ``Evaluator``/``make_eval_step`` the shared jitted eval layer.
"""
from repro.exec.engine import ChunkRunner, History, SimulationEngine
from repro.exec.evals import Evaluator, make_eval_step

__all__ = ["ChunkRunner", "History", "SimulationEngine", "Evaluator",
           "make_eval_step"]
