"""Attention: GQA + RoPE + optional sliding window.

Two execution paths:
  * ``chunked_attention`` — XLA-native online-softmax over KV chunks
    (lax.scan). O(S * chunk) transient memory, compiles on any backend;
    this is what the multi-pod dry-run lowers.
  * ``kernels.flash_attention`` — Pallas TPU kernel (same math), used on
    real TPU hardware and validated in interpret mode by tests.

Decode uses a KV cache; sliding-window archs use a ring-buffer cache of
size ``window`` so the long_500k cache is O(window), not O(S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, dense_init

NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * hd, dtype, cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype, cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype, cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }


def _split_heads(x, n_heads, hd):
    return x.reshape(*x.shape[:-1], n_heads, hd)


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def chunked_attention(q, k, v, q_positions, kv_positions, *, causal: bool,
                      window: int = 0, chunk: int = 512, unroll: bool = False):
    """Online-softmax attention, blocked over (q-block x kv-chunk).

    q: (B, Sq, H, hd); k/v: (B, Skv, H, hd) (kv already repeated to H heads).
    positions: (B, Sq) / (B, Skv) absolute positions (for masking).

    When queries and keys cover the SAME aligned range (self-attention,
    train/prefill), fully-masked kv chunks are skipped STRUCTURALLY: each
    q-block only visits kv chunks inside its causal frontier and sliding
    window — 2x FLOP saving for causal, ~S/window for SWA (§Perf H1-it3).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=2**30)
    n_chunks = k.shape[1] // chunk
    scale = hd ** -0.5
    qf = (q * scale).astype(jnp.float32)

    kc = k.reshape(B, n_chunks, chunk, H, hd)
    vc = v.reshape(B, n_chunks, chunk, H, hd)
    pc = kv_positions.reshape(B, n_chunks, chunk)

    def make_step(qb, q_pos_b):
        """Online-softmax update for one (q-block, kv-chunk) pair."""
        def step(carry, inp):
            m, l, acc = carry           # (B,H,qb), (B,H,qb), (B,H,qb,hd)
            kb, vb, pb = inp            # (B,chunk,H,hd), ..., (B,chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb.astype(jnp.float32))
            # padded KV slots carry position 2**30: always masked out
            valid = (pb < 2**29)[:, None, None, :]
            mask = jnp.logical_and(
                valid,
                pb[:, None, None, :] <= q_pos_b[:, None, :, None]
                if causal else True)
            if window:
                mask = jnp.logical_and(
                    mask, pb[:, None, None, :]
                    > q_pos_b[:, None, :, None] - window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None
        return step

    def run_range(qb, q_pos_b, k_lo, k_hi):
        """Online softmax of one q block over kv chunks [k_lo, k_hi)."""
        nb = qb.shape[1]
        m0 = jnp.full((B, H, nb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, nb), jnp.float32)
        a0 = jnp.zeros((B, H, nb, hd), jnp.float32)
        xs = (jnp.moveaxis(kc[:, k_lo:k_hi], 1, 0),
              jnp.moveaxis(vc[:, k_lo:k_hi], 1, 0),
              jnp.moveaxis(pc[:, k_lo:k_hi], 1, 0))
        (m, l, acc), _ = jax.lax.scan(
            make_step(qb, q_pos_b), (m0, l0, a0), xs,
            unroll=(k_hi - k_lo) if unroll else 1)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 1, 2)                 # (B, nb, H, hd)

    # structural chunk skipping needs statically-aligned self-attention
    aligned = causal and Sq == Skv and Sq % chunk == 0
    if not aligned:
        return run_range(qf, q_positions, 0, n_chunks).astype(q.dtype)

    n_q = Sq // chunk
    outs = []
    for qi in range(n_q):
        sl = slice(qi * chunk, (qi + 1) * chunk)
        hi = qi + 1                                    # causal frontier
        lo = max(0, (qi * chunk - window) // chunk) if window else 0
        outs.append(run_range(qf[:, sl], q_positions[:, sl], lo, hi))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def attention_fwd(p, cfg, x, positions, *, causal=True, kv_x=None,
                  kv_positions=None, window=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    kv_x: source of K/V (cross-attention) — defaults to x (self-attention).
    """
    hd = cfg.resolved_head_dim
    n_rep = cfg.num_heads // cfg.num_kv_heads
    kv_src = x if kv_x is None else kv_x
    kv_pos = positions if kv_positions is None else kv_positions
    q = _split_heads(dense(p["wq"], x), cfg.num_heads, hd)
    k = _split_heads(dense(p["wk"], kv_src), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], kv_src), cfg.num_kv_heads, hd)
    if causal or kv_x is None:           # rope only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    w = cfg.sliding_window if window is None else window
    out = chunked_attention(q, k, v, positions, kv_pos, causal=causal, window=w,
                            chunk=cfg.attn_chunk, unroll=cfg.unroll_chunks)
    return dense(p["wo"], out.reshape(*x.shape[:-1], cfg.num_heads * hd))


# ------------------------------------------------------------- decoding ----

def init_kv_cache(cfg, batch, max_len, dtype):
    """Ring-buffer cache when sliding_window > 0, else linear cache."""
    hd = cfg.resolved_head_dim
    L = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.full((batch, L), -1, jnp.int32),   # absolute positions held
    }


def attention_decode(p, cfg, x, cache, position):
    """One-token decode. x: (B, 1, d); position: (B,) absolute index."""
    hd = cfg.resolved_head_dim
    n_rep = cfg.num_heads // cfg.num_kv_heads
    B = x.shape[0]
    q = _split_heads(dense(p["wq"], x), cfg.num_heads, hd)
    k = _split_heads(dense(p["wk"], x), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], x), cfg.num_kv_heads, hd)
    q = apply_rope(q, position[:, None], cfg.rope_theta)
    k = apply_rope(k, position[:, None], cfg.rope_theta)

    L = cache["k"].shape[1]
    slot = (position % L).astype(jnp.int32)            # ring slot
    bidx = jnp.arange(B)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].at[bidx, slot].set(v[:, 0])
    new_pos = cache["pos"].at[bidx, slot].set(position)
    cache = {"k": new_k, "v": new_v, "pos": new_pos}

    kk = _repeat_kv(cache["k"], n_rep).astype(jnp.float32)
    vv = _repeat_kv(cache["v"], n_rep).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", (q * hd**-0.5).astype(jnp.float32), kk)
    valid = cache["pos"] >= 0
    mask = jnp.logical_and(valid, cache["pos"] <= position[:, None])
    if cfg.sliding_window:
        mask = jnp.logical_and(
            mask, cache["pos"] > position[:, None] - cfg.sliding_window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", a, vv).astype(x.dtype)
    out = out.reshape(B, 1, cfg.num_heads * hd)
    return dense(p["wo"], out), cache


def cross_attention_decode(p, cfg, x, enc_k, enc_v):
    """Cross-attention against precomputed encoder K/V.

    enc_k/enc_v: (B, S_enc, KH, hd) — computed once at the start of decode.
    x: (B, S, d) — S = 1 at decode time, a whole prompt chunk at prefill.
    """
    hd = cfg.resolved_head_dim
    n_rep = cfg.num_heads // cfg.num_kv_heads
    B, S, _ = x.shape
    q = _split_heads(dense(p["wq"], x), cfg.num_heads, hd)
    kk = _repeat_kv(enc_k, n_rep).astype(jnp.float32)
    vv = _repeat_kv(enc_v, n_rep).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", (q * hd**-0.5).astype(jnp.float32), kk)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", a, vv).astype(x.dtype)
    return dense(p["wo"], out.reshape(B, S, cfg.num_heads * hd))


# ----------------------------------------------------- chunked prefill -----

#: pad sentinel on the query/position axis of a prefill chunk: rows with
#: position >= PAD_FLOOR are padding — they never enter the cache and
#: their outputs are garbage the caller must drop (same convention as
#: chunked_attention's padded KV slots).
PAD_FLOOR = 2**29
PAD_POS = 2**30


def _chunk_slots(positions, ring_len):
    """Cache slots for one prefill chunk: consecutive from the chunk's
    FIRST position (which is always real), so pad rows land on distinct
    no-op slots instead of `PAD_POS % ring_len` colliding with a real
    write. Requires chunk <= ring_len (engine contract)."""
    c = positions.shape[1]
    return ((positions[:, :1] + jnp.arange(c, dtype=jnp.int32))
            % ring_len).astype(jnp.int32)


def attention_prefill(p, cfg, x, cache, positions):
    """Blockwise prefill of one prompt chunk against the decode cache.

    x: (B, c, d); positions: (B, c) absolute, consecutive from the
    chunk's first position; pad rows carry position >= PAD_FLOOR.

    BIT-IDENTITY CONTRACT (gated in tests/test_serve_plane.py): logits
    and cache leaves match the per-token ``attention_decode`` loop
    bitwise.
      * linear cache (window == 0): the whole chunk's K/V is written
        first; slots at future positions are masked to NEG_INF, whose
        softmax weight is exactly 0.0, so every query row reproduces
        the decode-time score vector elementwise.
      * ring cache (window > 0): a batched write evicts history that
        earlier in-chunk queries still need, so scores/values are
        SELECTED per query between the pre-write and post-write cache
        states — exactly the ring state the per-token path sees at each
        position. Transient memory is O(c * ring * H * hd) — the
        blockwise-prefill memory bound; requires c <= ring length.
    """
    hd = cfg.resolved_head_dim
    n_rep = cfg.num_heads // cfg.num_kv_heads
    B, c, _ = x.shape
    q = _split_heads(dense(p["wq"], x), cfg.num_heads, hd)
    k = _split_heads(dense(p["wk"], x), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], x), cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    L = cache["k"].shape[1]
    slots = _chunk_slots(positions, L)
    bidx = jnp.arange(B)[:, None]
    real = positions < PAD_FLOOR
    # pad rows write their slot's CURRENT entry back (a no-op write);
    # in-chunk slots are distinct, so no real write is clobbered
    k_w = jnp.where(real[..., None, None], k, cache["k"][bidx, slots])
    v_w = jnp.where(real[..., None, None], v, cache["v"][bidx, slots])
    p_w = jnp.where(real, positions, cache["pos"][bidx, slots])
    new = {"k": cache["k"].at[bidx, slots].set(k_w),
           "v": cache["v"].at[bidx, slots].set(v_w),
           "pos": cache["pos"].at[bidx, slots].set(p_w)}

    qf = (q * hd**-0.5).astype(jnp.float32)
    kk = _repeat_kv(new["k"], n_rep).astype(jnp.float32)
    vv = _repeat_kv(new["v"], n_rep).astype(jnp.float32)
    if not cfg.sliding_window:
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kk)
        mask = jnp.logical_and(new["pos"][:, None, :] >= 0,
                               new["pos"][:, None, :] <= positions[..., None])
        s = jnp.where(mask[:, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", a, vv).astype(x.dtype)
    else:
        kk_old = _repeat_kv(cache["k"], n_rep).astype(jnp.float32)
        vv_old = _repeat_kv(cache["v"], n_rep).astype(jnp.float32)
        s_new = jnp.einsum("bqhd,bkhd->bhqk", qf, kk)
        s_old = jnp.einsum("bqhd,bkhd->bhqk", qf, kk_old)
        # written[t, s]: slot s's in-chunk write happened at position <= t
        # (untouched slots keep new == old, so either branch is fine)
        written = jnp.logical_and(
            new["pos"][:, None, :] != cache["pos"][:, None, :],
            new["pos"][:, None, :] <= positions[..., None])
        pos_eff = jnp.where(written, new["pos"][:, None, :],
                            cache["pos"][:, None, :])
        s = jnp.where(written[:, None], s_new, s_old)
        mask = jnp.logical_and(pos_eff >= 0, pos_eff <= positions[..., None])
        mask = jnp.logical_and(
            mask, pos_eff > positions[..., None] - cfg.sliding_window)
        s = jnp.where(mask[:, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        v_eff = jnp.where(written[..., None, None],
                          vv[:, None], vv_old[:, None])
        out = jnp.einsum("bhqk,bqkhd->bqhd", a, v_eff).astype(x.dtype)
    out = out.reshape(B, c, cfg.num_heads * hd)
    return dense(p["wo"], out), new


# ----------------------------------------------------------- paged KV ------

def paged_view(pool, table):
    """Dense per-request view of a block pool.

    pool: {"k"/"v": (nb, bs, KH, hd), "pos": (nb, bs)}; table: (B, mb)
    int32 physical block ids per request (0 = the reserved null block).
    Returns (k, v, pos) shaped (B, mb*bs, ...) — the same layout as a
    dense linear/ring cache of length mb*bs, so the attention math (and
    its numerics) is shared with the dense-cache paths.
    """
    nb, bs = pool["pos"].shape
    blk = jnp.clip(table, 0, nb - 1)
    k = pool["k"][blk]                      # (B, mb, bs, KH, hd)
    v = pool["v"][blk]
    pos = jnp.where((table > 0)[..., None], pool["pos"][blk], -1)
    B, mb = table.shape
    return (k.reshape(B, mb * bs, *k.shape[3:]),
            v.reshape(B, mb * bs, *v.shape[3:]),
            pos.reshape(B, mb * bs))


def _paged_write(pool, table, slots, k, v, pos):
    """Scatter per-request logical ring slots into the pool.

    slots: (B, c) logical slots; k/v: (B, c, KH, hd); pos: (B, c).
    Requests own disjoint blocks, so cross-request writes never collide;
    slots within a request's chunk are distinct by the _chunk_slots
    contract. Rows whose table entry is 0 land in the null block.
    """
    nb, bs = pool["pos"].shape
    blk_i = slots // bs
    phys = jnp.clip(jnp.take_along_axis(table, blk_i, axis=1), 0, nb - 1)
    off = slots % bs
    return {"k": pool["k"].at[phys, off].set(k),
            "v": pool["v"].at[phys, off].set(v),
            "pos": pool["pos"].at[phys, off].set(pos)}


def attention_decode_paged(p, cfg, x, pool, table, ring_len, position):
    """One-token decode against the shared block pool.

    x: (B, 1, d); table: (B, mb); ring_len: (B,) per-request logical
    ring modulus (min(max_len, window) for SWA, the request's max_len
    otherwise); position: (B,) absolute. Same math as
    ``attention_decode`` on the gathered dense view.
    """
    hd = cfg.resolved_head_dim
    n_rep = cfg.num_heads // cfg.num_kv_heads
    B = x.shape[0]
    q = _split_heads(dense(p["wq"], x), cfg.num_heads, hd)
    k = _split_heads(dense(p["wk"], x), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], x), cfg.num_kv_heads, hd)
    q = apply_rope(q, position[:, None], cfg.rope_theta)
    k = apply_rope(k, position[:, None], cfg.rope_theta)

    slots = (position % ring_len).astype(jnp.int32)[:, None]
    pool = _paged_write(pool, table, slots, k, v, position[:, None])

    kk, vv, kpos = paged_view(pool, table)
    kk = _repeat_kv(kk, n_rep).astype(jnp.float32)
    vv = _repeat_kv(vv, n_rep).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", (q * hd**-0.5).astype(jnp.float32), kk)
    mask = jnp.logical_and(kpos >= 0, kpos <= position[:, None])
    if cfg.sliding_window:
        mask = jnp.logical_and(
            mask, kpos > position[:, None] - cfg.sliding_window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", a, vv).astype(x.dtype)
    out = out.reshape(B, 1, cfg.num_heads * hd)
    return dense(p["wo"], out), pool


def attention_prefill_paged(p, cfg, x, pool, table, ring_len, positions):
    """Blockwise prefill of one prompt chunk into the shared block pool —
    ``attention_prefill`` with the cache axes living behind a block
    table. Same pad-sentinel / selection semantics; requires
    chunk <= min(ring_len)."""
    hd = cfg.resolved_head_dim
    n_rep = cfg.num_heads // cfg.num_kv_heads
    B, c, _ = x.shape
    q = _split_heads(dense(p["wq"], x), cfg.num_heads, hd)
    k = _split_heads(dense(p["wk"], x), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], x), cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    nb, bs = pool["pos"].shape
    slots = ((positions[:, :1] + jnp.arange(c, dtype=jnp.int32))
             % ring_len[:, None]).astype(jnp.int32)
    blk_i = slots // bs
    phys = jnp.clip(jnp.take_along_axis(table, blk_i, axis=1), 0, nb - 1)
    off = slots % bs
    real = positions < PAD_FLOOR
    k_w = jnp.where(real[..., None, None], k, pool["k"][phys, off])
    v_w = jnp.where(real[..., None, None], v, pool["v"][phys, off])
    p_w = jnp.where(real, positions, pool["pos"][phys, off])

    old_k, old_v, old_pos = paged_view(pool, table)
    pool = {"k": pool["k"].at[phys, off].set(k_w),
            "v": pool["v"].at[phys, off].set(v_w),
            "pos": pool["pos"].at[phys, off].set(p_w)}
    new_k, new_v, new_pos = paged_view(pool, table)

    qf = (q * hd**-0.5).astype(jnp.float32)
    kk = _repeat_kv(new_k, n_rep).astype(jnp.float32)
    vv = _repeat_kv(new_v, n_rep).astype(jnp.float32)
    if not cfg.sliding_window:
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kk)
        mask = jnp.logical_and(new_pos[:, None, :] >= 0,
                               new_pos[:, None, :] <= positions[..., None])
        s = jnp.where(mask[:, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", a, vv).astype(x.dtype)
    else:
        kk_old = _repeat_kv(old_k, n_rep).astype(jnp.float32)
        vv_old = _repeat_kv(old_v, n_rep).astype(jnp.float32)
        s_new = jnp.einsum("bqhd,bkhd->bhqk", qf, kk)
        s_old = jnp.einsum("bqhd,bkhd->bhqk", qf, kk_old)
        written = jnp.logical_and(
            new_pos[:, None, :] != old_pos[:, None, :],
            new_pos[:, None, :] <= positions[..., None])
        pos_eff = jnp.where(written, new_pos[:, None, :],
                            old_pos[:, None, :])
        s = jnp.where(written[:, None], s_new, s_old)
        mask = jnp.logical_and(pos_eff >= 0, pos_eff <= positions[..., None])
        mask = jnp.logical_and(
            mask, pos_eff > positions[..., None] - cfg.sliding_window)
        s = jnp.where(mask[:, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        v_eff = jnp.where(written[..., None, None],
                          vv[:, None], vv_old[:, None])
        out = jnp.einsum("bhqk,bqkhd->bqhd", a, v_eff).astype(x.dtype)
    out = out.reshape(B, c, cfg.num_heads * hd)
    return dense(p["wo"], out), pool
