"""Attention: GQA + RoPE + optional sliding window.

Two execution paths:
  * ``chunked_attention`` — XLA-native online-softmax over KV chunks
    (lax.scan). O(S * chunk) transient memory, compiles on any backend;
    this is what the multi-pod dry-run lowers.
  * ``kernels.flash_attention`` — Pallas TPU kernel (same math), used on
    real TPU hardware and validated in interpret mode by tests.

Decode uses a KV cache; sliding-window archs use a ring-buffer cache of
size ``window`` so the long_500k cache is O(window), not O(S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, dense_init

NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * hd, dtype, cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype, cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype, cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }


def _split_heads(x, n_heads, hd):
    return x.reshape(*x.shape[:-1], n_heads, hd)


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def chunked_attention(q, k, v, q_positions, kv_positions, *, causal: bool,
                      window: int = 0, chunk: int = 512, unroll: bool = False):
    """Online-softmax attention, blocked over (q-block x kv-chunk).

    q: (B, Sq, H, hd); k/v: (B, Skv, H, hd) (kv already repeated to H heads).
    positions: (B, Sq) / (B, Skv) absolute positions (for masking).

    When queries and keys cover the SAME aligned range (self-attention,
    train/prefill), fully-masked kv chunks are skipped STRUCTURALLY: each
    q-block only visits kv chunks inside its causal frontier and sliding
    window — 2x FLOP saving for causal, ~S/window for SWA (§Perf H1-it3).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=2**30)
    n_chunks = k.shape[1] // chunk
    scale = hd ** -0.5
    qf = (q * scale).astype(jnp.float32)

    kc = k.reshape(B, n_chunks, chunk, H, hd)
    vc = v.reshape(B, n_chunks, chunk, H, hd)
    pc = kv_positions.reshape(B, n_chunks, chunk)

    def make_step(qb, q_pos_b):
        """Online-softmax update for one (q-block, kv-chunk) pair."""
        def step(carry, inp):
            m, l, acc = carry           # (B,H,qb), (B,H,qb), (B,H,qb,hd)
            kb, vb, pb = inp            # (B,chunk,H,hd), ..., (B,chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb.astype(jnp.float32))
            # padded KV slots carry position 2**30: always masked out
            valid = (pb < 2**29)[:, None, None, :]
            mask = jnp.logical_and(
                valid,
                pb[:, None, None, :] <= q_pos_b[:, None, :, None]
                if causal else True)
            if window:
                mask = jnp.logical_and(
                    mask, pb[:, None, None, :]
                    > q_pos_b[:, None, :, None] - window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None
        return step

    def run_range(qb, q_pos_b, k_lo, k_hi):
        """Online softmax of one q block over kv chunks [k_lo, k_hi)."""
        nb = qb.shape[1]
        m0 = jnp.full((B, H, nb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, nb), jnp.float32)
        a0 = jnp.zeros((B, H, nb, hd), jnp.float32)
        xs = (jnp.moveaxis(kc[:, k_lo:k_hi], 1, 0),
              jnp.moveaxis(vc[:, k_lo:k_hi], 1, 0),
              jnp.moveaxis(pc[:, k_lo:k_hi], 1, 0))
        (m, l, acc), _ = jax.lax.scan(
            make_step(qb, q_pos_b), (m0, l0, a0), xs,
            unroll=(k_hi - k_lo) if unroll else 1)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 1, 2)                 # (B, nb, H, hd)

    # structural chunk skipping needs statically-aligned self-attention
    aligned = causal and Sq == Skv and Sq % chunk == 0
    if not aligned:
        return run_range(qf, q_positions, 0, n_chunks).astype(q.dtype)

    n_q = Sq // chunk
    outs = []
    for qi in range(n_q):
        sl = slice(qi * chunk, (qi + 1) * chunk)
        hi = qi + 1                                    # causal frontier
        lo = max(0, (qi * chunk - window) // chunk) if window else 0
        outs.append(run_range(qf[:, sl], q_positions[:, sl], lo, hi))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def attention_fwd(p, cfg, x, positions, *, causal=True, kv_x=None,
                  kv_positions=None, window=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    kv_x: source of K/V (cross-attention) — defaults to x (self-attention).
    """
    hd = cfg.resolved_head_dim
    n_rep = cfg.num_heads // cfg.num_kv_heads
    kv_src = x if kv_x is None else kv_x
    kv_pos = positions if kv_positions is None else kv_positions
    q = _split_heads(dense(p["wq"], x), cfg.num_heads, hd)
    k = _split_heads(dense(p["wk"], kv_src), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], kv_src), cfg.num_kv_heads, hd)
    if causal or kv_x is None:           # rope only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    w = cfg.sliding_window if window is None else window
    out = chunked_attention(q, k, v, positions, kv_pos, causal=causal, window=w,
                            chunk=cfg.attn_chunk, unroll=cfg.unroll_chunks)
    return dense(p["wo"], out.reshape(*x.shape[:-1], cfg.num_heads * hd))


# ------------------------------------------------------------- decoding ----

def init_kv_cache(cfg, batch, max_len, dtype):
    """Ring-buffer cache when sliding_window > 0, else linear cache."""
    hd = cfg.resolved_head_dim
    L = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.full((batch, L), -1, jnp.int32),   # absolute positions held
    }


def attention_decode(p, cfg, x, cache, position):
    """One-token decode. x: (B, 1, d); position: (B,) absolute index."""
    hd = cfg.resolved_head_dim
    n_rep = cfg.num_heads // cfg.num_kv_heads
    B = x.shape[0]
    q = _split_heads(dense(p["wq"], x), cfg.num_heads, hd)
    k = _split_heads(dense(p["wk"], x), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], x), cfg.num_kv_heads, hd)
    q = apply_rope(q, position[:, None], cfg.rope_theta)
    k = apply_rope(k, position[:, None], cfg.rope_theta)

    L = cache["k"].shape[1]
    slot = (position % L).astype(jnp.int32)            # ring slot
    bidx = jnp.arange(B)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].at[bidx, slot].set(v[:, 0])
    new_pos = cache["pos"].at[bidx, slot].set(position)
    cache = {"k": new_k, "v": new_v, "pos": new_pos}

    kk = _repeat_kv(cache["k"], n_rep).astype(jnp.float32)
    vv = _repeat_kv(cache["v"], n_rep).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", (q * hd**-0.5).astype(jnp.float32), kk)
    valid = cache["pos"] >= 0
    mask = jnp.logical_and(valid, cache["pos"] <= position[:, None])
    if cfg.sliding_window:
        mask = jnp.logical_and(
            mask, cache["pos"] > position[:, None] - cfg.sliding_window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", a, vv).astype(x.dtype)
    out = out.reshape(B, 1, cfg.num_heads * hd)
    return dense(p["wo"], out), cache


def cross_attention_decode(p, cfg, x, enc_k, enc_v):
    """Decode-time cross-attention against precomputed encoder K/V.

    enc_k/enc_v: (B, S_enc, KH, hd) — computed once at the start of decode.
    """
    hd = cfg.resolved_head_dim
    n_rep = cfg.num_heads // cfg.num_kv_heads
    B = x.shape[0]
    q = _split_heads(dense(p["wq"], x), cfg.num_heads, hd)
    kk = _repeat_kv(enc_k, n_rep).astype(jnp.float32)
    vv = _repeat_kv(enc_v, n_rep).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", (q * hd**-0.5).astype(jnp.float32), kk)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", a, vv).astype(x.dtype)
    return dense(p["wo"], out.reshape(B, 1, cfg.num_heads * hd))
