"""Mamba-2 (SSD) block, as used by Zamba2 (arXiv:2411.15242).

Structured state-space duality with scalar-per-head decay:
    h_t = a_t * h_{t-1} + x_t (outer) B_t        h: (P, N) per head
    y_t = h_t @ C_t + D * x_t
with a_t = exp(-softplus(dt_t) * A), dt data-dependent, plus a short causal
conv on the (x, B, C) stream and a gated output (silu(z)).

Projections + conv run in parallel over the sequence; only the O(P*N)
state recurrence is a lax.scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, rmsnorm

HEAD_DIM = 64   # P


def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    N = cfg.ssm_state
    d_inner = 2 * d
    H = d_inner // HEAD_DIM
    ks = jax.random.split(key, 4)
    return {
        # in_proj -> [x (d_inner), z (d_inner), B (N), C (N), dt (H)]
        "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * N + H, dtype),
        "conv": 0.1 * jax.random.normal(
            ks[1], (cfg.conv_width, d_inner + 2 * N), jnp.float32).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_g": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[2], d_inner, d, dtype),
    }


def _dims(cfg):
    d = cfg.d_model
    d_inner = 2 * d
    H = d_inner // HEAD_DIM
    return d_inner, H, cfg.ssm_state


def _causal_conv(xbc, conv_w, conv_state):
    """xbc: (B, S, C); conv_w: (W, C); conv_state: (B, W-1, C) prior inputs."""
    W = conv_w.shape[0]
    ext = jnp.concatenate([conv_state, xbc], axis=1)     # (B, S+W-1, C)
    out = sum(ext[:, i:i + xbc.shape[1], :] * conv_w[i] for i in range(W))
    new_state = ext[:, -(W - 1):, :] if W > 1 else conv_state
    return jax.nn.silu(out), new_state


def init_mamba_state(cfg, batch, dtype):
    d_inner, H, N = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, H, HEAD_DIM, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_inner + 2 * N), dtype),
    }


def _project(p, cfg, u):
    d_inner, H, N = _dims(cfg)
    zxbcdt = dense(p["w_in"], u)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, xbc, dt


def mamba2_fwd(p, cfg, u, state):
    """Full-sequence forward. u: (B, S, d)."""
    B, S, d = u.shape
    d_inner, H, N = _dims(cfg)
    z, xbc, dt = _project(p, cfg, u)
    xbc, conv_state = _causal_conv(xbc, p["conv"], state["conv"])
    x = xbc[..., :d_inner].reshape(B, S, H, HEAD_DIM)
    Bm = xbc[..., d_inner:d_inner + N]
    Cm = xbc[..., d_inner + N:]

    A = -jnp.exp(p["A_log"])                              # (H,) negative
    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = jnp.exp(dt_s * A)                                 # decay in (0,1)
    xdt = x.astype(jnp.float32) * dt_s[..., None]         # dt-scaled input

    def step(h, inp):
        a_t, x_t, B_t, C_t = inp       # (B,H) (B,H,P) (B,N) (B,N)
        h = a_t[..., None, None] * h + x_t[..., :, None] * B_t[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    # chunked scan with per-chunk remat: backward memory O(S/chunk) states
    CH = 64
    pad = (-S) % CH
    def prep(x_, neutral=0.0):
        x_ = jnp.moveaxis(x_, 1, 0)
        if pad:
            x_ = jnp.pad(x_, ((0, pad),) + ((0, 0),) * (x_.ndim - 1),
                         constant_values=neutral)
        return x_.reshape((S + pad) // CH, CH, *x_.shape[1:])
    a_c = prep(a, neutral=1.0)         # padded steps: decay 1, input 0
    x_c = prep(xdt)
    B_c = prep(Bm.astype(jnp.float32))
    C_c = prep(Cm.astype(jnp.float32))

    @jax.checkpoint
    def chunk_step(h, inp):
        return jax.lax.scan(step, h, inp)

    h_new, ys = jax.lax.scan(chunk_step, state["ssm"], (a_c, x_c, B_c, C_c))
    ys = ys.reshape(S + pad, B, H, HEAD_DIM)[:S]
    y = jnp.moveaxis(ys, 0, 1)                            # (B,S,H,P)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(u.dtype)
    y = rmsnorm({"g": p["norm_g"]}, y) * jax.nn.silu(z)
    out = dense(p["w_out"], y)
    return out, dict(state, ssm=h_new, conv=conv_state)


def mamba2_step(p, cfg, u, state):
    """Single-token decode. u: (B, d)."""
    B, d = u.shape
    d_inner, H, N = _dims(cfg)
    z, xbc, dt = _project(p, cfg, u)
    # conv over ring of last W-1 inputs
    W = cfg.conv_width
    ext = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # (B,W,C)
    xbc_t = jax.nn.silu(jnp.sum(ext * p["conv"][None], axis=1))      # (B,C)
    new_conv = ext[:, 1:, :]
    x = xbc_t[..., :d_inner].reshape(B, H, HEAD_DIM)
    Bm = xbc_t[..., d_inner:d_inner + N].astype(jnp.float32)
    Cm = xbc_t[..., d_inner + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,H)
    a = jnp.exp(dt_s * A)
    xdt = x.astype(jnp.float32) * dt_s[..., None]
    h = a[..., None, None] * state["ssm"] + xdt[..., :, None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cm)
    y = y + p["D"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, d_inner).astype(u.dtype)
    y = rmsnorm({"g": p["norm_g"]}, y) * jax.nn.silu(z)
    out = dense(p["w_out"], y)
    return out, dict(state, ssm=h, conv=new_conv)
