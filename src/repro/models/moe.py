"""Mixture-of-Experts layer (GShard-style top-k dispatch with capacity).

Dispatch/combine are expressed as one-hot einsums so that GSPMD turns the
(token-sharded x expert-sharded) contraction into all-to-all traffic when
experts live on the "model" mesh axis — the communication pattern real
expert-parallel systems exhibit, visible to the roofline pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, uniform_init


def moe_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    scale = (1.0 / d) ** 0.5
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_in": uniform_init(ks[1], (E, d, f), scale, dtype),
        "w_gate": uniform_init(ks[2], (E, d, f), scale, dtype),
        "w_out": uniform_init(ks[3], (E, f, d), (1.0 / f) ** 0.5, dtype),
    }
    return p


def _capacity(tokens: int, cfg) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, ((cap + 7) // 8) * 8)   # pad to multiple of 8


def _dispatch_combine(xt, probs, cfg):
    """Capacity-based one-hot dispatch for a token group.

    xt: (T, d); probs: (T, E). Returns (out (T, d) f32-accumulated, aux).
    FLOPs of the dispatch/combine einsums are T*E*C*d with C = the group
    capacity — linear in T when called per fixed-size group, QUADRATIC in
    T when called once globally (C grows with T). See EXPERIMENTS §Perf.
    """
    T, d = xt.shape
    E, K = cfg.num_experts, cfg.top_k
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    C = _capacity(T, cfg)
    # position of each (token, k) within its expert's buffer
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)       # (T, K, E)
    # priority: k=0 assignments first, then token order
    flat = onehot.transpose(1, 0, 2).reshape(K * T, E)            # (K*T, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat               # (K*T, E)
    pos = pos_in_expert.reshape(K, T, E).transpose(1, 0, 2)       # (T, K, E)
    pos = jnp.sum(pos * onehot, axis=-1)                          # (T, K)
    keep = pos < C                                                # capacity drop
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch tensor: (T, E, C) one-hot weights
    disp = (jax.nn.one_hot(expert_idx, E, dtype=xt.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                             dtype=xt.dtype)[..., :C][:, :, None, :])
    disp = jnp.sum(disp, axis=1)                                  # (T, E, C)
    comb = jnp.sum(
        jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                         dtype=jnp.float32)[..., :C][:, :, None, :]
        * gate_vals[..., None, None].astype(jnp.float32),
        axis=1)                                                   # (T, E, C)
    return disp, comb


def _expert_ffn(p, xe):
    h = jnp.einsum("...ecd,edf->...ecf", xe, p["w_in"])
    g = jnp.einsum("...ecd,edf->...ecf", xe, p["w_gate"])
    return jnp.einsum("...ecf,efd->...ecd", jax.nn.silu(g) * h, p["w_out"])


def moe_apply(p, cfg, x):
    """x: (B, S, d) -> (B, S, d), plus aux load-balancing loss (scalar)."""
    B, S, d = x.shape
    E = cfg.num_experts
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)

    Gsz = cfg.moe_group_size
    if Gsz and T > Gsz and T % Gsz == 0:
        # blocked dispatch: fixed per-group capacity -> linear-in-T FLOPs.
        # The expert/capacity dims are constrained onto mesh axes so the
        # dispatch/combine einsums shard instead of computing redundantly
        # on every model shard (16x waste otherwise; §Perf H1-it4/it5):
        #   factorized mesh: E on "expert", C on "etp" (textbook EP+TP);
        #   E % model == 0:  E on "model" (pure expert parallel);
        #   otherwise:       C on "model" (capacity parallel).
        from repro.sharding.ctx import constrain, mesh_axis_names
        axes = mesh_axis_names()
        if "expert" in axes:
            d_ax, c_ax = (None, None, "expert", "etp"), \
                         (None, "expert", "etp", None)
        elif E % 16 == 0:
            d_ax, c_ax = (None, None, "model", None), \
                         (None, "model", None, None)
        else:
            d_ax, c_ax = (None, None, None, "model"), \
                         (None, None, "model", None)
        G = T // Gsz
        xg = xt.reshape(G, Gsz, d)
        pg = probs.reshape(G, Gsz, E)
        disp, comb = jax.vmap(lambda xx, pp: _dispatch_combine(xx, pp, cfg))(
            xg, pg)                                               # (G,Tb,E,C)
        disp = constrain(disp, *d_ax)
        comb = constrain(comb, *d_ax)
        xe = jnp.einsum("gtd,gtec->gecd", xg, disp)               # (G,E,C,d)
        xe = constrain(xe, *c_ax)
        ye = _expert_ffn(p, xe)
        ye = constrain(ye, *c_ax)
        out = jnp.einsum("gecd,gtec->gtd", ye.astype(jnp.float32), comb)
        out = out.reshape(T, d)
    else:
        disp, comb = _dispatch_combine(xt, probs, cfg)
        xe = jnp.einsum("td,tec->ecd", xt, disp)                  # (E, C, d)
        ye = _expert_ffn(p, xe)
        out = jnp.einsum("ecd,tec->td", ye.astype(jnp.float32), comb)

    # aux loss (Switch-style load balance)
    _, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32),
                  axis=0)
    aux = E * jnp.sum(me * ce)

    return out.reshape(B, S, d).astype(x.dtype), aux


def moe_apply_dense(p, cfg, x):
    """Decode-path MoE: tiny token count, dense gather is cheaper than
    capacity dispatch. x: (B, 1, d)."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)       # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    w = jnp.sum(jax.nn.one_hot(expert_idx, cfg.num_experts,
                               dtype=jnp.float32)
                * gate_vals[..., None], axis=1)                   # (T, E)
    h = jnp.einsum("td,edf->tef", xt, p["w_in"])
    g = jnp.einsum("td,edf->tef", xt, p["w_gate"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * h, p["w_out"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), w)
    return out.reshape(B, S, d).astype(x.dtype), jnp.float32(0.0)
