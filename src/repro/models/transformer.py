"""Parameterised decoder stack covering dense / MoE / VLM / RWKV6 / hybrid.

The stack is split into BODY and TAIL block groups so the paper's FES
scheme (feature extractor = embed + body; classifier = tail + final norm +
lm head) is a first-class param-tree boundary, not an afterthought.

Homogeneous blocks are stacked along a leading layer axis and applied with
``lax.scan`` — keeps HLO size O(1) in depth (126-layer archs compile fast).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, moe, rwkv6
from repro.models.layers import (dense, dense_init, embedding, embedding_init,
                                 mlp, mlp_init, rmsnorm, rmsnorm_init)


# ------------------------------------------------------------- blocks ------

def block_init(key, cfg, dtype):
    """One block of the arch's family."""
    if cfg.family == "ssm":                       # rwkv6
        return {"rwkv": rwkv6.rwkv6_init(key, cfg, dtype),
                "ln1": rmsnorm_init(cfg.d_model, dtype),
                "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if cfg.family == "hybrid":                    # zamba2 mamba block
        return {"mamba": mamba2.mamba2_init(key, cfg, dtype),
                "ln": rmsnorm_init(cfg.d_model, dtype)}
    ks = jax.random.split(key, 2)
    p = {"ln1": rmsnorm_init(cfg.d_model, dtype),
         "ln2": rmsnorm_init(cfg.d_model, dtype),
         "attn": attn.attn_init(ks[0], cfg, dtype)}
    if cfg.num_experts:
        p["moe"] = moe.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, cfg.mlp_gated)
    return p


def _stacked_block_init(key, cfg, n, dtype):
    keys = jax.random.split(key, max(n, 1))[:n]
    if n == 0:
        return None
    return jax.vmap(lambda k: block_init(k, cfg, dtype))(keys)


def block_fwd(p, cfg, x, positions, aux):
    """Full-sequence block application. Returns (x, aux)."""
    if cfg.family == "ssm":
        B = x.shape[0]
        st = rwkv6.init_rwkv_state(cfg, B, x.dtype)
        h, st = rwkv6.time_mix(p["rwkv"], cfg, rmsnorm(p["ln1"], x), st)
        x = x + h
        h, _ = rwkv6.channel_mix(p["rwkv"], rmsnorm(p["ln2"], x), st)
        return x + h, aux
    if cfg.family == "hybrid":
        B = x.shape[0]
        st = mamba2.init_mamba_state(cfg, B, x.dtype)
        h, _ = mamba2.mamba2_fwd(p["mamba"], cfg, rmsnorm(p["ln"], x), st)
        return x + h, aux
    h = attn.attention_fwd(p["attn"], cfg, rmsnorm(p["ln1"], x), positions)
    x = x + h
    if cfg.num_experts:
        h, a = moe.moe_apply(p["moe"], cfg, rmsnorm(p["ln2"], x))
        aux = aux + a
    else:
        h = mlp(p["mlp"], rmsnorm(p["ln2"], x))
    return x + h, aux


def _scan_blocks(stacked, cfg, x, positions, aux, shared_attn=None):
    """Apply a stacked group of blocks with lax.scan (+remat)."""
    if stacked is None:
        return x, aux

    def body(carry, layer_p):
        x, aux = carry
        if cfg.shard_residuals:
            # the scan carry is what checkpoint saves per layer: keep it
            # model-sharded so the residual stack is 16x smaller
            from repro.sharding.ctx import constrain
            x = constrain(x, None, None, "model")
        x, aux = block_fwd(layer_p, cfg, x, positions, aux)
        return (x, aux), None

    if cfg.remat:
        body = jax.checkpoint(body)

    def _scan(f, c, xs):
        n = jax.tree.leaves(xs)[0].shape[0]
        return jax.lax.scan(f, c, xs, unroll=n if cfg.unroll_layers else 1)

    L = jax.tree.leaves(stacked)[0].shape[0]
    if (cfg.family == "hybrid" and cfg.attn_every and shared_attn is not None
            and L >= cfg.attn_every):
        # group the mamba blocks; apply the SHARED attention block between
        # groups (Zamba2: one attention param set reused across depth).
        per = cfg.attn_every
        G = L // per
        rest = L - G * per
        grouped = jax.tree.map(
            lambda a: a[: G * per].reshape(G, per, *a.shape[1:]), stacked)

        def group_body(carry, group_p):
            x, aux = carry
            (x, aux), _ = _scan(body, (x, aux), group_p)
            h = attn.attention_fwd(
                shared_attn["attn"], cfg, rmsnorm(shared_attn["ln"], x),
                positions)
            return (x + h, aux), None

        (x, aux), _ = _scan(group_body, (x, aux), grouped)
        if rest:
            tail_p = jax.tree.map(lambda a: a[G * per:], stacked)
            (x, aux), _ = _scan(body, (x, aux), tail_p)
        return x, aux

    (x, aux), _ = _scan(body, (x, aux), stacked)
    return x, aux


# ------------------------------------------------------------- params ------

def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    n_tail = min(cfg.fes_tail_layers, cfg.num_layers)
    n_body = cfg.num_layers - n_tail
    params = {
        "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "body": _stacked_block_init(ks[1], cfg, n_body, dtype),
        "tail": _stacked_block_init(ks[2], cfg, n_tail, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype),
    }
    if cfg.family == "hybrid" and cfg.attn_every:
        acfg = cfg.with_(num_heads=cfg.num_heads or 32,
                         num_kv_heads=cfg.num_kv_heads or 32)
        params["shared_attn"] = {
            "attn": attn.attn_init(ks[4], acfg, dtype),
            "ln": rmsnorm_init(cfg.d_model, dtype),
        }
    if cfg.family == "vlm":
        params["vision_proj"] = dense_init(
            ks[5], cfg.vision_dim or cfg.d_model, cfg.d_model, dtype)
    return params


# ------------------------------------------------------------ forward ------

def embed_inputs(params, cfg, batch):
    """Returns (x, positions, label_offset). VLM prepends patch embeddings."""
    tokens = batch["tokens"]
    x = embedding(params["embed"], tokens)
    if cfg.family == "vlm":
        pe = dense(params["vision_proj"], batch["patch_emb"].astype(x.dtype))
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def forward(params, cfg, batch):
    """Full-sequence logits (train / prefill)."""
    x, positions = embed_inputs(params, cfg, batch)
    aux = jnp.float32(0.0)
    x, aux = _scan_blocks(params["body"], cfg, x, positions, aux,
                          params.get("shared_attn"))
    x, aux = _scan_blocks(params["tail"], cfg, x, positions, aux,
                          params.get("shared_attn"))
    x = rmsnorm(params["final_norm"], x)
    logits = dense(params["lm_head"], x)
    return logits, aux


def hidden_states(params, cfg, batch):
    """Final-norm hidden states (no logits)."""
    x, positions = embed_inputs(params, cfg, batch)
    aux = jnp.float32(0.0)
    x, aux = _scan_blocks(params["body"], cfg, x, positions, aux,
                          params.get("shared_attn"))
    x, aux = _scan_blocks(params["tail"], cfg, x, positions, aux,
                          params.get("shared_attn"))
    return rmsnorm(params["final_norm"], x), aux


def loss_fn(params, cfg, batch):
    """Next-token CE (+ MoE aux), chunked over the sequence so the logits
    never materialise at (B, S, V). VLM: loss on the text segment only."""
    from repro.models.layers import chunked_cross_entropy
    x, aux = hidden_states(params, cfg, batch)
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        x = x[:, -tokens.shape[1]:, :]
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:]), jnp.zeros_like(tokens[:, :1])], axis=1)
    loss = chunked_cross_entropy(x, params["lm_head"], labels, mask,
                                 unroll=cfg.unroll_chunks)
    return loss + 0.01 * aux


def prefill_logits(params, cfg, batch):
    """Full-sequence prefill, last-position logits only (dry-run costing;
    full (B, S, V) logits are never formed). The cache-writing chunked
    prefill for serving is ``prefill`` below."""
    x, _ = hidden_states(params, cfg, batch)
    return dense(params["lm_head"], x[:, -1, :])


# ------------------------------------------------------------- decode ------

def init_decode_cache(cfg, batch, max_len, dtype=None):
    """Per-layer decode state stacked along the layer axis."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_tail = min(cfg.fes_tail_layers, cfg.num_layers)
    n_body = cfg.num_layers - n_tail

    def one(_):
        if cfg.family == "ssm":
            return rwkv6.init_rwkv_state(cfg, batch, dtype)
        if cfg.family == "hybrid":
            return mamba2.init_mamba_state(cfg, batch, dtype)
        return attn.init_kv_cache(cfg, batch, max_len, dtype)

    def stack(n):
        if n == 0:
            return None
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[one(i) for i in range(n)])

    cache = {"body": stack(n_body), "tail": stack(n_tail)}
    if cfg.family == "hybrid" and cfg.attn_every:
        G = n_body // cfg.attn_every  # shared-attn KV caches (one per group site)
        if G > 0:
            cache["shared"] = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[attn.init_kv_cache(cfg, batch, max_len, dtype)
                  for _ in range(G)])
    return cache


def block_decode(p, cfg, x, cache, position):
    """One-token block application. x: (B, 1, d)."""
    if cfg.family == "ssm":
        h, cache = rwkv6.time_mix_step(p["rwkv"], cfg,
                                       rmsnorm(p["ln1"], x)[:, 0], cache)
        x = x + h[:, None]
        h, cache = rwkv6.channel_mix(p["rwkv"], rmsnorm(p["ln2"], x)[:, 0],
                                     cache, single=True)
        return x + h[:, None], cache
    if cfg.family == "hybrid":
        h, cache = mamba2.mamba2_step(p["mamba"], cfg,
                                      rmsnorm(p["ln"], x)[:, 0], cache)
        return x + h[:, None], cache
    h, cache = attn.attention_decode(p["attn"], cfg, rmsnorm(p["ln1"], x),
                                     cache, position)
    x = x + h
    if cfg.num_experts:
        h, _ = moe.moe_apply_dense(p["moe"], cfg, rmsnorm(p["ln2"], x))
    else:
        h = mlp(p["mlp"], rmsnorm(p["ln2"], x))
    return x + h, cache


def _scan_blocks_decode(stacked, cfg, x, cache, position, shared_attn=None,
                        shared_cache=None):
    if stacked is None:
        return x, cache, shared_cache

    def body(carry, inp):
        x = carry
        layer_p, layer_c = inp
        x, layer_c = block_decode(layer_p, cfg, x, layer_c, position)
        return x, layer_c

    def _scan(f, c, xs):
        n = jax.tree.leaves(xs)[0].shape[0]
        return jax.lax.scan(f, c, xs, unroll=n if cfg.unroll_layers else 1)

    L = jax.tree.leaves(stacked)[0].shape[0]
    if (cfg.family == "hybrid" and cfg.attn_every and shared_attn is not None
            and shared_cache is not None and L >= cfg.attn_every):
        per = cfg.attn_every
        G = L // per
        grouped_p = jax.tree.map(
            lambda a: a[: G * per].reshape(G, per, *a.shape[1:]), stacked)
        grouped_c = jax.tree.map(
            lambda a: a[: G * per].reshape(G, per, *a.shape[1:]), cache)

        def group_body(x, inp):
            gp, gc, sc = inp
            x, gc = _scan(body, x, (gp, gc))
            h, sc = attn.attention_decode(
                shared_attn["attn"], cfg, rmsnorm(shared_attn["ln"], x), sc,
                position)
            return x + h, (gc, sc)

        x, (grouped_c, shared_cache) = _scan(
            group_body, x, (grouped_p, grouped_c, shared_cache))
        new_cache = jax.tree.map(
            lambda a: a.reshape(G * per, *a.shape[2:]), grouped_c)
        rest = L - G * per
        if rest:
            tail_p = jax.tree.map(lambda a: a[G * per:], stacked)
            tail_c = jax.tree.map(lambda a: a[G * per:], cache)
            x, tail_c = _scan(body, x, (tail_p, tail_c))
            new_cache = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), new_cache, tail_c)
        return x, new_cache, shared_cache

    x, cache = _scan(body, x, (stacked, cache))
    return x, cache, shared_cache


def decode_step(params, cfg, token, position, cache):
    """token: (B,) int32; position: (B,). Returns (logits (B, V), cache)."""
    x = embedding(params["embed"], token[:, None])
    x, body_c, shared_c = _scan_blocks_decode(
        params["body"], cfg, x, cache["body"], position,
        params.get("shared_attn"), cache.get("shared"))
    x, tail_c, _ = _scan_blocks_decode(
        params["tail"], cfg, x, cache["tail"], position)
    x = rmsnorm(params["final_norm"], x)
    logits = dense(params["lm_head"], x)[:, 0]
    new_cache = {"body": body_c, "tail": tail_c}
    if shared_c is not None:
        new_cache["shared"] = shared_c
    return logits, new_cache


# ---------------------------------------------------- chunked prefill ------

def block_prefill(p, cfg, x, cache, positions):
    """One prompt chunk through one block. x: (B, c, d). Attention-family
    blocks only (ssm/hybrid keep the per-token path); the FFN half reuses
    the decode-path ops (moe_apply_dense / mlp) so the residual stream
    matches ``block_decode`` bitwise row-for-row."""
    h, cache = attn.attention_prefill(p["attn"], cfg, rmsnorm(p["ln1"], x),
                                      cache, positions)
    x = x + h
    if cfg.num_experts:
        h, _ = moe.moe_apply_dense(p["moe"], cfg, rmsnorm(p["ln2"], x))
    else:
        h = mlp(p["mlp"], rmsnorm(p["ln2"], x))
    return x + h, cache


def _scan_blocks_prefill(stacked, cfg, x, cache, positions):
    if stacked is None:
        return x, cache

    def body(x, inp):
        layer_p, layer_c = inp
        x, layer_c = block_prefill(layer_p, cfg, x, layer_c, positions)
        return x, layer_c

    n = jax.tree.leaves(stacked)[0].shape[0]
    x, cache = jax.lax.scan(body, x, (stacked, cache),
                            unroll=n if cfg.unroll_layers else 1)
    return x, cache


def prefill(params, cfg, tokens, positions, cache):
    """Jitted chunked prefill: one dispatch per prompt CHUNK instead of
    per token. tokens/positions: (B, c); pad rows carry positions >=
    attn.PAD_FLOOR and never enter the cache. Returns (logits (B, c, V),
    cache) — bit-identical to looping ``decode_step`` over the chunk
    (gated in tests/test_serve_plane.py)."""
    x = embedding(params["embed"], tokens)
    x, body_c = _scan_blocks_prefill(params["body"], cfg, x,
                                     cache["body"], positions)
    x, tail_c = _scan_blocks_prefill(params["tail"], cfg, x,
                                     cache["tail"], positions)
    x = rmsnorm(params["final_norm"], x)
    logits = dense(params["lm_head"], x)
    return logits, {"body": body_c, "tail": tail_c}


# --------------------------------------------------------- paged cache -----

def init_paged_pool(cfg, num_blocks, block_size, dtype=None):
    """Block pool shared by all in-flight requests: per layer-group leaves
    (n_layers, num_blocks, block_size, KH, hd) + pos (n_layers, nb, bs).
    Block 0 is reserved as the null/trash block (block-table entry 0 =
    unmapped)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    n_tail = min(cfg.fes_tail_layers, cfg.num_layers)
    n_body = cfg.num_layers - n_tail

    def group(n):
        if n == 0:
            return None
        return {"k": jnp.zeros((n, num_blocks, block_size,
                                cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((n, num_blocks, block_size,
                                cfg.num_kv_heads, hd), dtype),
                "pos": jnp.full((n, num_blocks, block_size), -1, jnp.int32)}

    return {"body": group(n_body), "tail": group(n_tail)}


def _scan_blocks_paged(stacked, cfg, x, pool, table, ring_len, positions,
                       prefill_chunk):
    if stacked is None:
        return x, pool

    def body(x, inp):
        layer_p, layer_pool = inp
        if prefill_chunk:
            h, layer_pool = attn.attention_prefill_paged(
                layer_p["attn"], cfg, rmsnorm(layer_p["ln1"], x),
                layer_pool, table, ring_len, positions)
        else:
            h, layer_pool = attn.attention_decode_paged(
                layer_p["attn"], cfg, rmsnorm(layer_p["ln1"], x),
                layer_pool, table, ring_len, positions)
        x = x + h
        if cfg.num_experts:
            h, _ = moe.moe_apply_dense(layer_p["moe"], cfg,
                                       rmsnorm(layer_p["ln2"], x))
        else:
            h = mlp(layer_p["mlp"], rmsnorm(layer_p["ln2"], x))
        return x + h, layer_pool

    n = jax.tree.leaves(stacked)[0].shape[0]
    x, pool = jax.lax.scan(body, x, (stacked, pool),
                           unroll=n if cfg.unroll_layers else 1)
    return x, pool


def decode_step_paged(params, cfg, token, position, pool, table, ring_len):
    """One decode step against the shared block pool. token/position: (B,);
    table: (B, mb) block ids (0 = unmapped); ring_len: (B,) logical ring
    modulus per request. Returns (logits (B, V), pool)."""
    x = embedding(params["embed"], token[:, None])
    x, body_p = _scan_blocks_paged(params["body"], cfg, x, pool["body"],
                                   table, ring_len, position, False)
    x, tail_p = _scan_blocks_paged(params["tail"], cfg, x, pool["tail"],
                                   table, ring_len, position, False)
    x = rmsnorm(params["final_norm"], x)
    logits = dense(params["lm_head"], x)[:, 0]
    return logits, {"body": body_p, "tail": tail_p}


def prefill_paged(params, cfg, tokens, positions, pool, table, ring_len):
    """Chunked prefill against the shared block pool. tokens/positions:
    (B, c). Returns (logits (B, c, V), pool)."""
    x = embedding(params["embed"], tokens)
    x, body_p = _scan_blocks_paged(params["body"], cfg, x, pool["body"],
                                   table, ring_len, positions, True)
    x, tail_p = _scan_blocks_paged(params["tail"], cfg, x, pool["tail"],
                                   table, ring_len, positions, True)
    x = rmsnorm(params["final_norm"], x)
    logits = dense(params["lm_head"], x)
    return logits, {"body": body_p, "tail": tail_p}
