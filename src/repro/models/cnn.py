"""The paper's own task model: 2 conv (5x5) + 3 FC layers for 28x28 images.

This is the model AMA-FES is evaluated on (MNIST / FMNIST, Section V).
FES split is exactly the paper's: feature extractor = the conv layers,
classifier = the three FC layers ("all the computing-limited devices ...
train only the final three FC layers").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import cross_entropy_loss, dense, dense_init


def init_params(cfg, key):
    ks = jax.random.split(key, 5)
    c1, c2 = 10, 20
    p = {
        # feature extractor (conv) — paper's omega^f
        "body": {
            "conv1": {"w": 0.1 * jax.random.normal(ks[0], (5, 5, 1, c1))},
            "conv2": {"w": 0.1 * jax.random.normal(ks[1], (5, 5, c1, c2))},
        },
        # classifier (3 FC) — paper's omega^c
        "fc1": dense_init(ks[2], 4 * 4 * c2, 120, jnp.float32, bias=True),
        "fc2": dense_init(ks[3], 120, 84, jnp.float32, bias=True),
        "fc3": dense_init(ks[4], 84, cfg.vocab_size, jnp.float32, bias=True),
    }
    return p


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def forward(params, cfg, batch):
    """batch: {"image": (B, 28, 28, 1)} -> logits (B, n_classes)."""
    x = batch["image"].astype(jnp.float32)
    x = jax.nn.relu(_conv(x, params["body"]["conv1"]["w"]))     # (B,24,24,10)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                              (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = jax.nn.relu(_conv(x, params["body"]["conv2"]["w"]))     # (B,8,8,20)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                              (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)                               # (B, 320)
    x = jax.nn.relu(dense(params["fc1"], x))
    x = jax.nn.relu(dense(params["fc2"], x))
    return dense(params["fc3"], x), jnp.float32(0.0)


def loss_fn(params, cfg, batch):
    logits, _ = forward(params, cfg, batch)
    return cross_entropy_loss(logits, batch["label"])


def accuracy(params, cfg, batch):
    logits, _ = forward(params, cfg, batch)
    return jnp.mean((jnp.argmax(logits, -1) == batch["label"]).astype(jnp.float32))
