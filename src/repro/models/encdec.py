"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the mel/conv frontend is a STUB: ``input_specs`` hands the
model precomputed frame embeddings (B, encoder_seq, d_model). We implement
the transformer backbone: non-causal encoder, causal decoder with
cross-attention, cached decode (self-KV ring + precomputed cross-KV).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (dense, dense_init, embedding,
                                 embedding_init, mlp, mlp_init,
                                 rmsnorm, rmsnorm_init)


def _enc_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn.attn_init(ks[0], cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, cfg.mlp_gated)}


def _dec_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "self_attn": attn.attn_init(ks[0], cfg, dtype),
            "ln_x": rmsnorm_init(cfg.d_model, dtype),
            "cross_attn": attn.attn_init(ks[1], cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype, cfg.mlp_gated)}


def _stack(key, n, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    n_tail = min(cfg.fes_tail_layers, cfg.num_layers)
    n_body = cfg.num_layers - n_tail
    return {
        "enc_pos": 0.02 * jax.random.normal(
            ks[0], (cfg.encoder_seq, cfg.d_model), jnp.float32).astype(dtype),
        "encoder": _stack(ks[1], cfg.encoder_layers,
                          lambda k: _enc_block_init(k, cfg, dtype)),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "embed": embedding_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "body": _stack(ks[3], n_body, lambda k: _dec_block_init(k, cfg, dtype)),
        "tail": _stack(ks[4], n_tail, lambda k: _dec_block_init(k, cfg, dtype)),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(ks[5], cfg.d_model, cfg.vocab_size, dtype),
    }


def encode(params, cfg, frame_emb):
    x = frame_emb.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][None]
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, p):
        h = attn.attention_fwd(p["attn"], cfg, rmsnorm(p["ln1"], x), pos,
                               causal=False, window=0)
        x = x + h
        return x + mlp(p["mlp"], rmsnorm(p["ln2"], x)), None

    if cfg.remat:
        body = jax.checkpoint(body)
    n = jax.tree.leaves(params["encoder"])[0].shape[0]
    x, _ = jax.lax.scan(body, x, params["encoder"],
                        unroll=n if cfg.unroll_layers else 1)
    return rmsnorm(params["enc_norm"], x)


def _dec_block_fwd(p, cfg, x, pos, enc_out, enc_pos):
    h = attn.attention_fwd(p["self_attn"], cfg, rmsnorm(p["ln1"], x), pos)
    x = x + h
    h = attn.attention_fwd(p["cross_attn"], cfg, rmsnorm(p["ln_x"], x), pos,
                           causal=False, kv_x=enc_out, kv_positions=enc_pos,
                           window=0)
    x = x + h
    return x + mlp(p["mlp"], rmsnorm(p["ln2"], x))


def _dec_scan(stacked, cfg, x, pos, enc_out, enc_pos):
    def body(x, p):
        return _dec_block_fwd(p, cfg, x, pos, enc_out, enc_pos), None
    if cfg.remat:
        body = jax.checkpoint(body)
    n = jax.tree.leaves(stacked)[0].shape[0]
    x, _ = jax.lax.scan(body, x, stacked,
                        unroll=n if cfg.unroll_layers else 1)
    return x


def forward(params, cfg, batch):
    """batch: {"frame_emb": (B, enc_seq, d), "tokens": (B, S)}."""
    enc_out = encode(params, cfg, batch["frame_emb"])
    B, Se, _ = enc_out.shape
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    x = embedding(params["embed"], batch["tokens"])
    S = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _dec_scan(params["body"], cfg, x, pos, enc_out, enc_pos)
    x = _dec_scan(params["tail"], cfg, x, pos, enc_out, enc_pos)
    x = rmsnorm(params["final_norm"], x)
    return dense(params["lm_head"], x), jnp.float32(0.0)


def hidden_states(params, cfg, batch):
    enc_out = encode(params, cfg, batch["frame_emb"])
    B, Se, _ = enc_out.shape
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    x = embedding(params["embed"], batch["tokens"])
    S = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _dec_scan(params["body"], cfg, x, pos, enc_out, enc_pos)
    x = _dec_scan(params["tail"], cfg, x, pos, enc_out, enc_pos)
    return rmsnorm(params["final_norm"], x)


def loss_fn(params, cfg, batch):
    from repro.models.layers import chunked_cross_entropy
    x = hidden_states(params, cfg, batch)
    tokens = batch["tokens"]
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:]), jnp.zeros_like(tokens[:, :1])], axis=1)
    return chunked_cross_entropy(x, params["lm_head"], labels, mask,
                                 unroll=cfg.unroll_chunks)


def prefill_logits(params, cfg, batch):
    x = hidden_states(params, cfg, batch)
    return dense(params["lm_head"], x[:, -1, :])


# ------------------------------------------------------------- decode ------

def _split_kv(p, cfg, enc_out):
    hd = cfg.resolved_head_dim
    k = dense(p["wk"], enc_out).reshape(*enc_out.shape[:-1], cfg.num_kv_heads, hd)
    v = dense(p["wv"], enc_out).reshape(*enc_out.shape[:-1], cfg.num_kv_heads, hd)
    return k, v


def init_decode_cache(params, cfg, frame_emb, max_len, dtype=None):
    """Encode once; precompute per-layer cross-KV; fresh self-KV rings."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, frame_emb)
    B = enc_out.shape[0]

    def cross_kv(stacked):
        def one(p):
            return _split_kv(p["cross_attn"], cfg, enc_out)
        return jax.vmap(one, in_axes=(0,))(stacked)      # (L, B, Se, KH, hd)

    def self_kv(stacked):
        L = jax.tree.leaves(stacked)[0].shape[0]
        return jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[attn.init_kv_cache(cfg, B, max_len, dtype) for _ in range(L)])

    return {
        "body_self": self_kv(params["body"]),
        "tail_self": self_kv(params["tail"]),
        "body_cross": cross_kv(params["body"]),
        "tail_cross": cross_kv(params["tail"]),
    }


def _dec_scan_decode(stacked, cfg, x, position, self_c, cross_c):
    def body(x, inp):
        p, sc, cc = inp
        h, sc = attn.attention_decode(p["self_attn"], cfg,
                                      rmsnorm(p["ln1"], x), sc, position)
        x = x + h
        ck, cv = cc
        h = attn.cross_attention_decode(p["cross_attn"], cfg,
                                        rmsnorm(p["ln_x"], x), ck, cv)
        x = x + h
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x))
        return x, sc

    n = jax.tree.leaves(stacked)[0].shape[0]
    x, self_c = jax.lax.scan(body, x, (stacked, self_c, cross_c),
                             unroll=n if cfg.unroll_layers else 1)
    return x, self_c


def decode_step(params, cfg, token, position, cache):
    x = embedding(params["embed"], token[:, None])
    x, body_self = _dec_scan_decode(params["body"], cfg, x, position,
                                    cache["body_self"], cache["body_cross"])
    x, tail_self = _dec_scan_decode(params["tail"], cfg, x, position,
                                    cache["tail_self"], cache["tail_cross"])
    x = rmsnorm(params["final_norm"], x)
    logits = dense(params["lm_head"], x)[:, 0]
    return logits, dict(cache, body_self=body_self, tail_self=tail_self)


# ---------------------------------------------------- chunked prefill ------

def _dec_scan_prefill(stacked, cfg, x, positions, self_c, cross_c):
    def body(x, inp):
        p, sc, cc = inp
        h, sc = attn.attention_prefill(p["self_attn"], cfg,
                                       rmsnorm(p["ln1"], x), sc, positions)
        x = x + h
        ck, cv = cc
        h = attn.cross_attention_decode(p["cross_attn"], cfg,
                                        rmsnorm(p["ln_x"], x), ck, cv)
        x = x + h
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x))
        return x, sc

    n = jax.tree.leaves(stacked)[0].shape[0]
    x, self_c = jax.lax.scan(body, x, (stacked, self_c, cross_c),
                             unroll=n if cfg.unroll_layers else 1)
    return x, self_c


def prefill(params, cfg, tokens, positions, cache):
    """Chunked decoder prefill against the cached decode state (self-KV
    rings written blockwise; cross-KV read batched). tokens/positions:
    (B, c); pad rows carry positions >= attn.PAD_FLOOR. Returns (logits
    (B, c, V), cache) bit-identical to the per-token decode loop."""
    x = embedding(params["embed"], tokens)
    x, body_self = _dec_scan_prefill(params["body"], cfg, x, positions,
                                     cache["body_self"], cache["body_cross"])
    x, tail_self = _dec_scan_prefill(params["tail"], cfg, x, positions,
                                     cache["tail_self"], cache["tail_cross"])
    x = rmsnorm(params["final_norm"], x)
    logits = dense(params["lm_head"], x)
    return logits, dict(cache, body_self=body_self, tail_self=tail_self)
