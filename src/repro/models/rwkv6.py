"""RWKV-6 ("Finch") block: data-dependent decay linear attention.

Faithful to arXiv:2404.05892 at the block level:
  * token shift (learned per-channel lerp with previous token),
  * low-rank data-dependent decay  w_t = exp(-exp(w0 + tanh(x A) B)),
  * per-head state recurrence  S_t = diag(w_t) S_{t-1} + k_t^T v_t,
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t),
  * per-head group-norm, silu(g) gate, output projection,
  * squared-ReLU channel mixing.

The recurrence runs as a lax.scan over time (projections are computed for
the whole sequence in parallel; only the O(d*hd) state update is serial).
``kernels/rwkv6_scan.py`` provides the Pallas chunked version for TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, layernorm

HEAD_DIM = 64
DECAY_RANK = 32


def rwkv6_init(key, cfg, dtype):
    d = cfg.d_model
    H = d // HEAD_DIM
    ks = jax.random.split(key, 10)
    return {
        "mix": 0.5 * jnp.ones((5, d), dtype),            # r,k,v,w,g token-shift
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        "wo": dense_init(ks[4], d, d, dtype),
        # data-dependent decay (low-rank)
        "w0": jnp.full((d,), -4.0, dtype),
        "wA": dense_init(ks[5], d, DECAY_RANK, dtype),
        "wB": dense_init(ks[6], DECAY_RANK, d, dtype),
        "u": jnp.zeros((H, HEAD_DIM), dtype),            # bonus
        "ln_x": {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        # channel mix
        "cmix": 0.5 * jnp.ones((2, d), dtype),
        "ck": dense_init(ks[7], d, int(3.5 * d) if cfg.d_ff == 0 else cfg.d_ff, dtype),
        "cv": dense_init(ks[8], int(3.5 * d) if cfg.d_ff == 0 else cfg.d_ff, d, dtype),
        "cr": dense_init(ks[9], d, d, dtype),
    }


def _token_shift(x, x_prev_last):
    """shift x right by one along seq; position 0 gets x_prev_last."""
    return jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)


def _decay(p, xw):
    lr = jnp.tanh(dense(p["wA"], xw)) @ p["wB"]["w"]
    logw = -jnp.exp(
        jnp.clip(p["w0"].astype(jnp.float32) + lr.astype(jnp.float32), -8.0, 4.0))
    return jnp.exp(logw)                                 # in (0, 1)


def init_rwkv_state(cfg, batch, dtype):
    d = cfg.d_model
    H = d // HEAD_DIM
    return {
        "wkv": jnp.zeros((batch, H, HEAD_DIM, HEAD_DIM), jnp.float32),
        "x_tm": jnp.zeros((batch, d), dtype),            # time-mix shift state
        "x_cm": jnp.zeros((batch, d), dtype),            # channel-mix shift state
    }


def time_mix(p, cfg, x, state):
    """Full-sequence forward. x: (B, S, d). Returns (y, new_state)."""
    B, S, d = x.shape
    H = d // HEAD_DIM
    xs = _token_shift(x, state["x_tm"])
    mixed = [x + p["mix"][i] * (xs - x) for i in range(5)]
    xr, xk, xv, xw, xg = mixed
    r = dense(p["wr"], xr).reshape(B, S, H, HEAD_DIM)
    k = dense(p["wk"], xk).reshape(B, S, H, HEAD_DIM)
    v = dense(p["wv"], xv).reshape(B, S, H, HEAD_DIM)
    g = dense(p["wg"], xg)
    w = _decay(p, xw).reshape(B, S, H, HEAD_DIM)         # (0,1)

    u = p["u"].astype(jnp.float32)

    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp                         # (B,H,hd) each
        r_t = r_t.astype(jnp.float32)                    # f32 inside the
        k_t = k_t.astype(jnp.float32)                    # step only: scan
        v_t = v_t.astype(jnp.float32)                    # inputs stay bf16
        w_t = w_t.astype(jnp.float32)                    # (halves the
        kv = k_t[..., :, None] * v_t[..., None, :]       # resharding bytes
        y = jnp.einsum("bhi,bhij->bhj", r_t, S_ + u[..., None] * kv)
        S_ = w_t[..., :, None] * S_ + kv                 # around the head
        return S_, y                                     # reshape, §Perf H2)

    # Two-level chunked scan: outer scan saves the O(H*hd*hd) state only at
    # chunk boundaries (per-chunk remat), so training backward memory is
    # O(S/chunk) states instead of O(S) — the TPU adaptation of the CUDA
    # wkv kernel's chunked recomputation.
    CH = 64
    pad = (-S) % CH
    def prep(a):
        a = jnp.moveaxis(a, 1, 0)                        # (S,B,H,hd)
        if pad:
            a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        return a.reshape((S + pad) // CH, CH, *a.shape[1:])
    rs, ks_, vs, ws = prep(r), prep(k), prep(v), prep(w)
    # pad decay with ones so padded steps keep the state unchanged
    if pad:
        ws = ws.at[-1, CH - pad:].set(jnp.asarray(1.0, ws.dtype))

    @jax.checkpoint
    def chunk_step(S_, inp):
        return jax.lax.scan(step, S_, inp)

    S_new, ys = jax.lax.scan(chunk_step, state["wkv"], (rs, ks_, vs, ws))
    ys = ys.reshape(S + pad, B, H, HEAD_DIM)[:S]
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d).astype(x.dtype)

    y = layernorm(p["ln_x"], y)                          # group-norm proxy
    y = y * jax.nn.silu(g)
    out = dense(p["wo"], y)
    new_state = dict(state, wkv=S_new, x_tm=x[:, -1, :])
    return out, new_state


def time_mix_step(p, cfg, x, state):
    """Single-token decode. x: (B, d)."""
    B, d = x.shape
    H = d // HEAD_DIM
    xs = state["x_tm"]
    mixed = [x + p["mix"][i] * (xs - x) for i in range(5)]
    xr, xk, xv, xw, xg = mixed
    r = dense(p["wr"], xr).reshape(B, H, HEAD_DIM).astype(jnp.float32)
    k = dense(p["wk"], xk).reshape(B, H, HEAD_DIM).astype(jnp.float32)
    v = dense(p["wv"], xv).reshape(B, H, HEAD_DIM).astype(jnp.float32)
    g = dense(p["wg"], xg)
    w = _decay(p, xw).reshape(B, H, HEAD_DIM)
    u = p["u"].astype(jnp.float32)
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", r, state["wkv"] + u[..., None] * kv)
    S_new = w[..., :, None] * state["wkv"] + kv
    y = y.reshape(B, d).astype(x.dtype)
    y = layernorm(p["ln_x"], y) * jax.nn.silu(g)
    out = dense(p["wo"], y)
    return out, dict(state, wkv=S_new, x_tm=x)


def channel_mix(p, x, state, single: bool = False):
    if single:
        xs = state["x_cm"]
        new_last = x
    else:
        xs = _token_shift(x, state["x_cm"])
        new_last = x[:, -1, :]
    xk = x + p["cmix"][0] * (xs - x)
    xr = x + p["cmix"][1] * (xs - x)
    k = jnp.square(jax.nn.relu(dense(p["ck"], xk)))
    out = jax.nn.sigmoid(dense(p["cr"], xr)) * dense(p["cv"], k)
    return out, dict(state, x_cm=new_last)
