"""Unified model API: every architecture exposes the same surface.

    model = build_model(cfg)
    params = model.init(key)
    loss   = model.loss(params, batch)            # train / FL local step
    logits, aux = model.forward(params, batch)    # full-seq (prefill)
    logits, cache = model.decode_step(params, token, position, cache)
    mask   = model.fes_mask(params)               # paper Eq.(2) split: True = classifier

``input_specs`` builds ShapeDtypeStruct stand-ins for the multi-pod dry-run
(no allocation). Modality frontends (audio conv codec, ViT) are stubs per
the assignment: specs hand the backbone precomputed frame/patch embeddings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import cnn, encdec, transformer


# Top-level param keys that constitute the paper's "classifier" (omega^c).
CLASSIFIER_KEYS = ("tail", "final_norm", "lm_head", "fc1", "fc2", "fc3")


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Any], Any]
    loss: Callable[[Any, Any], jax.Array]
    forward: Callable[[Any, Any], Any]
    decode_step: Callable[..., Any] | None
    init_decode_cache: Callable[..., Any] | None
    #: last-position logits over a full padded batch (dry-run costing)
    prefill_logits: Callable[[Any, Any], Any] | None = None
    #: chunked prefill(params, tokens, positions, cache) -> (logits, cache);
    #: bit-identical to looping decode_step (None = per-token only family)
    prefill: Callable[..., Any] | None = None
    #: paged-KV serving surface (attention families only)
    init_paged_pool: Callable[..., Any] | None = None
    decode_step_paged: Callable[..., Any] | None = None
    prefill_paged: Callable[..., Any] | None = None

    def fes_mask(self, params):
        """True leaves = trainable under FES (the classifier omega^c)."""
        return {
            k: jax.tree.map(lambda _: k in CLASSIFIER_KEYS, v)
            for k, v in params.items()
        }


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "cnn":
        return Model(
            cfg=cfg,
            init=lambda key: cnn.init_params(cfg, key),
            loss=lambda p, b: cnn.loss_fn(p, cfg, b),
            forward=lambda p, b: cnn.forward(p, cfg, b),
            decode_step=None,
            init_decode_cache=None,
        )
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            loss=lambda p, b: encdec.loss_fn(p, cfg, b),
            forward=lambda p, b: encdec.forward(p, cfg, b),
            decode_step=lambda p, tok, pos, cache: encdec.decode_step(
                p, cfg, tok, pos, cache),
            init_decode_cache=lambda p, frame_emb, max_len: encdec.init_decode_cache(
                p, cfg, frame_emb, max_len),
            prefill_logits=lambda p, b: encdec.prefill_logits(p, cfg, b),
            prefill=lambda p, toks, pos, cache: encdec.prefill(
                p, cfg, toks, pos, cache),
        )
    # ssm/hybrid decode through recurrent state, not a KV ring: chunked
    # prefill and the paged pool only apply to the attention families.
    attn_family = cfg.family in ("dense", "moe", "vlm")
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        loss=lambda p, b: transformer.loss_fn(p, cfg, b),
        forward=lambda p, b: transformer.forward(p, cfg, b),
        decode_step=lambda p, tok, pos, cache: transformer.decode_step(
            p, cfg, tok, pos, cache),
        init_decode_cache=lambda p, batch, max_len: transformer.init_decode_cache(
            cfg, batch, max_len),
        prefill_logits=lambda p, b: transformer.prefill_logits(p, cfg, b),
        prefill=(lambda p, toks, pos, cache: transformer.prefill(
            p, cfg, toks, pos, cache)) if attn_family else None,
        init_paged_pool=(lambda nb, bs: transformer.init_paged_pool(
            cfg, nb, bs)) if attn_family else None,
        decode_step_paged=(lambda p, tok, pos, pool, table, lw:
                           transformer.decode_step_paged(
                               p, cfg, tok, pos, pool, table, lw))
        if attn_family else None,
        prefill_paged=(lambda p, toks, pos, pool, table, lw:
                       transformer.prefill_paged(
                           p, cfg, toks, pos, pool, table, lw))
        if attn_family else None,
    )


# --------------------------------------------------------- input specs -----

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for a (arch x input-shape) pair.

    train/prefill -> {"batch": {...}}
    decode        -> {"token", "position", "cache"} (cache built structurally
                     via eval_shape so no memory is touched).
    """
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    if cfg.family == "cnn":
        return {"batch": {"image": _sds((B, 28, 28, 1), jnp.float32),
                          "label": _sds((B,), jnp.int32)}}

    if shape.kind in ("train", "prefill"):
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            batch["patch_emb"] = _sds(
                (B, cfg.num_patches, cfg.vision_dim or cfg.d_model), dt)
        if cfg.family == "audio":
            batch["frame_emb"] = _sds((B, cfg.encoder_seq, cfg.d_model), dt)
        return {"batch": batch}

    # decode: one new token against a seq_len-sized KV cache/state
    token = _sds((B,), jnp.int32)
    position = _sds((B,), jnp.int32)
    if cfg.family == "audio":
        params_shape = jax.eval_shape(
            lambda k: encdec.init_params(cfg, k), jax.random.PRNGKey(0))
        frame_sds = _sds((B, cfg.encoder_seq, cfg.d_model), dt)
        cache = jax.eval_shape(
            lambda p, f: encdec.init_decode_cache(p, cfg, f, S),
            params_shape, frame_sds)
    else:
        cache = jax.eval_shape(
            lambda: transformer.init_decode_cache(cfg, B, S))
    return {"token": token, "position": position, "cache": cache}
