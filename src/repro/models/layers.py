"""Core neural-net primitives (pure JAX, functional params-as-pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def uniform_init(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, dtype=jnp.float32, minval=-scale,
                              maxval=scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, bias: bool = False):
    """Fan-in scaled init (matches torch.nn.Linear default scale)."""
    scale = (1.0 / d_in) ** 0.5
    p = {"w": uniform_init(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embedding_init(key, vocab, d, dtype):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32).astype(dtype) * 0.02}


def embedding(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def rmsnorm_init(d, dtype):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- rotary ----

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLP ----

def mlp_init(key, d_model, d_ff, dtype, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d_model, d_ff, dtype),
         "w_out": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(p, x):
    h = dense(p["w_in"], x)
    if "w_gate" in p:
        h = jax.nn.silu(dense(p["w_gate"], x)) * h
    else:
        h = jax.nn.gelu(h)
    return dense(p["w_out"], h)


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token-level cross entropy in f32. logits (..., V), labels (...).

    The gold-logit pick is an iota-compare masked reduction (NOT
    take_along_axis): it fuses into the vocab reduction and stays sharded
    when V lives on the "model" mesh axis, instead of forcing GSPMD to
    replicate the full logits for a gather.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(labels.dtype, logits.shape,
                                    logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_cross_entropy(x, head, labels, mask, *, chunk: int = 1024,
                          unroll: bool = False):
    """Sequence-chunked CE: logits are materialised one seq-chunk at a time
    (per-chunk remat), so peak memory is O(B * chunk * V) instead of
    O(B * S * V) — the dominant temp buffer for large-vocab archs.

    x: (B, S, d) final hidden states; head: lm_head param dict;
    labels/mask: (B, S). Returns mean nll over mask.
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = x.shape[1] // chunk
    xc = jnp.moveaxis(x.reshape(B, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    def body(tot, inp):
        xs, ls, ms = inp
        logits = dense(head, xs).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(ls.dtype, logits.shape, 2)
        gold = jnp.sum(jnp.where(iota == ls[..., None], logits, 0.0), -1)
        msf = ms.astype(jnp.float32)
        return tot + jnp.sum((logz - gold) * msf), None

    body_ck = jax.checkpoint(body)
    tot, _ = jax.lax.scan(body_ck, jnp.float32(0.0), (xc, lc, mc),
                          unroll=n if unroll else 1)
    return tot / jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
