"""Comm-plane implementations: bf16 / q8 / top-k with error feedback.

Every plane operates on the SAME flat per-dtype-group layout the fused
server kernels use (``kernels.server_plane._dtype_groups``): the stacked
client deltas ``x_k - prev`` are concatenated to one (K, N_g) matrix per
dtype group, compressed there, and handed to the server reduction as
``groups = [(leaf_idxs, payload)]`` — the exact input shape of
``server_mix_compressed_tree``. Error-feedback residual state lives in
the same flat layout, one ``(C, N_g)`` f32 array per group keyed
``"g0"``/``"g1"``/..., carried through the round scan as
``aux["comm"]`` so checkpoints and the shadow metrics tap see it like
any other strategy state.

Determinism contract (scan == loop == resume): ``compress`` is a pure
function of ``(t, prev, client_params, residual)`` — the q8 stochastic
rounding draws its uniforms from ``fold_in(fold_in(PRNGKey(seed), t),
group)``, never from carried RNG state, so replaying round t from a
checkpoint reproduces the exact quantization noise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.server_plane import _cat, _co_leaves, _dtype_groups

_REGISTRY: dict = {}

# Salt for the stochastic-rounding key stream so comm noise is
# decorrelated from every other seed-derived stream in the engine.
_COMM_SALT = 0x00C0FFEE


def register(cls):
    """Class decorator: register a CommPlane under cls.name (+ aliases)."""
    _REGISTRY[cls.name] = cls
    for alias in getattr(cls, "aliases", ()):
        _REGISTRY[alias] = cls
    return cls


def names():
    return sorted(set(_REGISTRY))


def get(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown comm plane {name!r}; known: none|{'|'.join(names())}"
        ) from None


def resolve(fl):
    """FLConfig -> CommPlane instance, or None for the dense path.

    ``None`` is the contract for ``comm_plane="none"``: the round engine
    must take its pre-comm branch untouched (bit-identity with the
    dense engine is a tested invariant, not an accident)."""
    name = getattr(fl, "comm_plane", "none")
    if name in ("none", "", None):
        return None
    return get(name)(fl)


def dense_bytes(params) -> int:
    """Bytes of one dense uncompressed upload of ``params``."""
    return sum(int(x.size) * jnp.asarray(x).dtype.itemsize
               for x in jax.tree.leaves(params))


def wire_fraction(fl) -> float:
    """Nominal compressed/dense payload ratio for the bandwidth env.

    The environment layer prices airtime before a model exists, so this
    is the plane's asymptotic ratio vs an f32 dense upload (per-group
    scale words and index overheads amortise away at model scale);
    ``bytes_on_wire_compressed`` in the metrics uses the exact per-model
    ``payload_bytes`` instead."""
    name = getattr(fl, "comm_plane", "none")
    if name in ("none", "", None):
        return 1.0
    cls = get(name)
    return cls.nominal_fraction(fl)


class CommPlane:
    """Base class: compress stacked client deltas before the reduction.

    Subclasses implement ``_encode(key, e) -> (payload, dq)`` on one
    flat (K, N) f32 error matrix ``e`` (delta + residual); the base
    class owns grouping, error feedback, reconstruction and byte
    accounting. ``payload`` kinds are the ``server_mix_compressed_tree``
    contract: ``{"kind": "delta", "d": (K,N) int8|bf16, "scale": (K,)}``
    or ``{"kind": "topk", "v": (K,kk) f32, "i": (K,kk) int32}``."""

    name = "base"
    aliases: tuple = ()

    def __init__(self, fl):
        self.fl = fl
        self.error_feedback = bool(getattr(fl, "comm_error_feedback", True))

    # -- residual state ----------------------------------------------------
    def init_residual(self, params, cohort: int):
        """{"g0": (cohort, N_0) f32, ...} zeros, one entry per dtype
        group of ``params`` — or {} when error feedback is off."""
        if not self.error_feedback:
            return {}
        leaves = jax.tree.leaves(params)
        res = {}
        for gi, idxs in enumerate(_dtype_groups(leaves).values()):
            n = sum(int(leaves[i].size) for i in idxs)
            res[f"g{gi}"] = jnp.zeros((cohort, n), jnp.float32)
        return res

    # -- compression -------------------------------------------------------
    def compress(self, t, prev_global, client_params, residual):
        """(groups, new_residual): quantize stacked deltas per group.

        Pure in (t, arrays): safe under scan/jit/donation. ``residual``
        must match ``init_residual`` (possibly {})."""
        leaves_p, treedef = jax.tree.flatten(prev_global)
        leaves_c = _co_leaves(client_params, treedef)
        base = jax.random.fold_in(
            jax.random.PRNGKey(self.fl.seed ^ _COMM_SALT),
            jnp.asarray(t, jnp.uint32))
        groups, new_res = [], {}
        for gi, idxs in enumerate(_dtype_groups(leaves_p).values()):
            K = leaves_c[idxs[0]].shape[0]
            d = _cat([
                leaves_c[i].reshape(K, -1).astype(jnp.float32)
                - leaves_p[i].reshape(-1).astype(jnp.float32)[None]
                for i in idxs])
            rk = f"g{gi}"
            e = d + residual[rk] if rk in residual else d
            payload, dq = self._encode(jax.random.fold_in(base, gi), e)
            if self.error_feedback:
                new_res[rk] = e - dq
            groups.append((idxs, payload))
        return groups, new_res

    # -- reconstruction (reduced path / strategies without a fused hook) ---
    def reconstruct(self, prev_global, groups):
        """Stacked client tree ``prev + dequant(payload)`` — what the
        server would have seen had the clients uploaded the compressed
        deltas and the server densified them. Used by the pre-reduction
        path and by strategies without a ``compressed_server_update``."""
        leaves_p, treedef = jax.tree.flatten(prev_global)
        out = [None] * len(leaves_p)
        for idxs, payload in groups:
            fp = _cat([leaves_p[i].reshape(-1) for i in idxs])
            dq = decode(payload, int(fp.shape[0]))
            flat = fp.astype(jnp.float32)[None, :] + dq
            K = flat.shape[0]
            off = 0
            for i in idxs:
                n = int(leaves_p[i].size)
                out[i] = (flat[:, off:off + n]
                          .reshape((K,) + leaves_p[i].shape)
                          .astype(leaves_p[i].dtype))
                off += n
        return treedef.unflatten(out)

    # -- byte accounting ---------------------------------------------------
    def payload_bytes(self, params) -> int:
        """Exact bytes one client uploads for one round (static)."""
        leaves = jax.tree.leaves(params)
        total = 0
        for idxs in _dtype_groups(leaves).values():
            total += self._group_bytes(
                sum(int(leaves[i].size) for i in idxs))
        return total

    # -- subclass hooks ----------------------------------------------------
    def _encode(self, key, e):
        raise NotImplementedError

    def _group_bytes(self, n: int) -> int:
        raise NotImplementedError

    @classmethod
    def nominal_fraction(cls, fl) -> float:
        raise NotImplementedError


def decode(payload, n: int):
    """Dequantize one flat payload to its dense (K, n) f32 delta."""
    if payload["kind"] == "delta":
        return (payload["d"].astype(jnp.float32)
                * payload["scale"][:, None].astype(jnp.float32))
    if payload["kind"] == "topk":
        K = payload["v"].shape[0]
        rows = jnp.arange(K, dtype=jnp.int32)[:, None]
        return (jnp.zeros((K, n), jnp.float32)
                .at[rows, payload["i"].astype(jnp.int32)]
                .add(payload["v"].astype(jnp.float32)))
    raise ValueError(f"unknown payload kind {payload['kind']!r}")


def q8_encode(key, e):
    """Stochastic int8 rows: scale = max|e| / 127 per row, q = ⌊y + u⌋.

    Unbiased (E[q·scale] = e) and bounded: |e - q·scale| ≤ scale
    elementwise, since |y| ≤ 127 by construction and ⌊y + u⌋ with
    u ∈ [0, 1) lands on one of the two integers bracketing y."""
    amax = jnp.max(jnp.abs(e), axis=-1)
    scale = jnp.maximum(amax, jnp.float32(1e-30)) / jnp.float32(127.0)
    y = e / scale[:, None]
    u = jax.random.uniform(key, e.shape, jnp.float32)
    q = jnp.clip(jnp.floor(y + u), -127.0, 127.0).astype(jnp.int8)
    payload = {"kind": "delta", "d": q, "scale": scale}
    return payload, q.astype(jnp.float32) * scale[:, None]


def bf16_encode(e):
    """bf16 rows, unit scale. The rounding error of an f32 under bf16
    truncation is exactly representable in f32 (the dropped low 16
    mantissa bits), so error feedback telescopes EXACTLY: compressed
    round sums + final residual == dense sums bitwise."""
    q = e.astype(jnp.bfloat16)
    scale = jnp.ones((e.shape[0],), jnp.float32)
    payload = {"kind": "delta", "d": q, "scale": scale}
    return payload, q.astype(jnp.float32)


def topk_encode(e, kk: int):
    """Keep the kk largest-|.| entries per row as (value, position)."""
    _, idx = jax.lax.top_k(jnp.abs(e), kk)
    idx = idx.astype(jnp.int32)
    vals = jnp.take_along_axis(e, idx, axis=-1)
    payload = {"kind": "topk", "v": vals, "i": idx}
    K = e.shape[0]
    rows = jnp.arange(K, dtype=jnp.int32)[:, None]
    dq = jnp.zeros(e.shape, jnp.float32).at[rows, idx].add(vals)
    return payload, dq


@register
class Bf16Plane(CommPlane):
    """Deltas cast to bfloat16 (2x vs f32), exact error feedback."""

    name = "bf16"

    def _encode(self, key, e):
        del key
        return bf16_encode(e)

    def _group_bytes(self, n: int) -> int:
        return 2 * n

    @classmethod
    def nominal_fraction(cls, fl) -> float:
        return 0.5


@register
class Q8Plane(CommPlane):
    """Stochastic-rounded int8 deltas + per-row f32 scale (~4x)."""

    name = "q8"
    aliases = ("int8",)

    def _encode(self, key, e):
        return q8_encode(key, e)

    def _group_bytes(self, n: int) -> int:
        return n + 4        # int8 payload + one f32 scale word

    @classmethod
    def nominal_fraction(cls, fl) -> float:
        return 0.25


@register
class TopKPlane(CommPlane):
    """Top-k magnitude sparsification: keep ``comm_topk_frac`` of each
    dtype group as (f32 value, int32 position) pairs."""

    name = "topk"

    def __init__(self, fl):
        super().__init__(fl)
        self.frac = float(getattr(fl, "comm_topk_frac", 0.01))
        if not 0.0 < self.frac <= 1.0:
            raise ValueError(
                f"comm_topk_frac must be in (0, 1], got {self.frac}")

    def _kk(self, n: int) -> int:
        return max(1, min(n, int(self.frac * n)))

    def _encode(self, key, e):
        del key
        return topk_encode(e, self._kk(int(e.shape[-1])))

    def _group_bytes(self, n: int) -> int:
        return 8 * self._kk(n)      # f32 value + int32 position per entry

    @classmethod
    def nominal_fraction(cls, fl) -> float:
        frac = float(getattr(fl, "comm_topk_frac", 0.01))
        return min(1.0, 2.0 * frac)
