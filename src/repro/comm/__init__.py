"""The pluggable communication plane: compressed client→server uplinks.

The paper's wireless-heterogeneity half models WHEN an update arrives
(delay rounds, fading channels, bandwidth deadlines) but the engine
always shipped full-precision dense deltas — the environments were
derating clients from a fictional payload. A ``CommPlane`` closes that
gap: it compresses the stacked client deltas (x_k - prev) BEFORE the
server reduction, with per-cohort error-feedback residual state carried
as strategy aux (so the fused scan, the --no-scan loop and --resume all
stay bit-identical), and the server consumes the compressed payload
through fused dequantize-accumulate kernels
(``kernels.server_plane.server_mix_compressed_tree``) — decompression
rides the server's one HBM pass per round instead of materialising a
dense f32 copy.

Registered planes (``FLConfig.comm_plane`` / ``--comm-plane``):

  * ``none`` — the dense full-precision path, bit-identical to the
    engine before this module existed (``resolve`` returns None and the
    round engine takes its original branch);
  * ``bf16`` — deltas cast to bfloat16 (2x), error feedback exact: the
    f32 residual of a bf16 rounding is exactly representable, so
    compressed-sum + residual telescopes to the dense sum bitwise;
  * ``q8``  — int8 stochastic-rounded quantization with one f32 scale
    per cohort per dtype group (~4x); the rounding key is a pure
    function of (seed, t), keeping scan == loop == resume;
  * ``topk`` — top-k magnitude sparsification (``comm_topk_frac`` of
    each dtype group survives as (value, position) pairs), served by
    the sparse-scatter kernel.

Adding a plane is one class: subclass ``CommPlane`` in ``plane.py``,
decorate with ``@register``, and every entry point (round engine,
launcher, benchmarks, bandwidth environment) picks it up.
"""
from __future__ import annotations

from repro.comm.plane import (Bf16Plane, CommPlane, Q8Plane,  # noqa: F401
                              TopKPlane, dense_bytes, get, names, register,
                              resolve, wire_fraction)

__all__ = ["CommPlane", "Bf16Plane", "Q8Plane", "TopKPlane", "register",
           "names", "get", "resolve", "wire_fraction", "dense_bytes"]
