"""Fused server-plane kernels: the COMPLETE server update in one HBM pass.

The per-round server hot loop — staleness/participation weight
computation from the schedule, weighted accumulation of the stacked
(K, N) client params, the AMA mix (with the async ring buffer where the
environment has delays), and the optional FedOpt server-Adam moment
update — is purely HBM-bandwidth-bound at LLM scale. Before this module
each stage was a separate jnp pass materialising (N,)/(K, N)/(Q, N)
intermediates; here each round is ONE ``pl.pallas_call`` over a 1-D grid
of flat parameter tiles:

  * ``server_mix_flat``   — sync plane (ama / fedavg / fedprox):
        streams K+1 rows in, 1 out; weights + alpha schedule in-kernel.
  * ``server_async_flat`` — async plane (async_ama, Eqs. 6-11):
        streams K+Q+1 rows in, Q+1 out; gamma^-(delays), ring-buffer
        enqueue, slot pop and the alpha/beta/gamma mix fused.
  * ``server_adam_flat``  — FedOpt server-Adam:
        streams K+3 rows in, 3 out; pseudo-gradient, moments and the
        model step fused.

Each kernel body calls the SAME math as the pure-jnp oracle
(``kernels/ref.py: server_*_math``), so interpret mode matches the
reference to within 1-2 ulp (bit-exact up to XLA's shape-dependent
multiply-add contraction); compiled TPU mode is allclose. The
``server_*_tree`` drivers flatten a whole param pytree to one vector per
dtype group (bf16 and f32 leaves keep their dtypes), so the engine
dispatches ONE fused pass per round per dtype group instead of a chain
of per-leaf jnp ops.

Dispatch policy (``impl`` below / ``fl.server_plane``): the Pallas
pallas_call is the TPU lowering; OFF-TPU the "fused" impl runs the
jitted flat oracle instead — XLA CPU fuses the whole flat op sequence
into one pass, which is where the measured CPU win comes from
(BENCH_server_plane.json), while the Pallas INTERPRETER is a pure
emulation layer that is orders of magnitude slower and exists only to
validate the kernel body (impl="interpret", CI parity tests).

Block sizing (TPU/interpret path): tiles are (block,) flat lanes;
``(K + Q + 2) * block * 4`` bytes must fit VMEM on TPU (~16 MB) —
128k lanes keeps K=10, Q=16 under that budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

DEFAULT_BLOCK = 128 * 1024

__all__ = ["server_mix_flat", "server_async_flat", "server_adam_flat",
           "server_mix_delta_flat", "server_mix_scatter_flat",
           "server_mix_tree", "server_async_tree", "server_adam_tree",
           "server_mix_compressed_tree", "mix_coefs", "DEFAULT_BLOCK"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# The "ref" impl runs the oracle math under jit so XLA applies the same
# multiply-add contraction it applies to the interpret-mode kernel body —
# that (plus the shared op sequence) keeps ref == interpret within
# 1-2 ulp even when called eagerly (contraction is shape-dependent, so
# strict bit-equality across different blockings is not guaranteed).
_ref_mix = jax.jit(ref.server_mix_math)
_ref_async = jax.jit(ref.server_async_math)
_ref_adam = jax.jit(ref.server_adam_math)


def _route(impl: str) -> tuple[bool, bool]:
    """Resolve an impl name to (use_pallas_kernel, interpret_flag):

      "fused"     — the production path: pallas_call on TPU, the jitted
                    flat oracle off-TPU (one XLA fusion; the Pallas
                    INTERPRETER is emulation, not a perf path);
      "ref"       — always the jitted flat oracle;
      "interpret" — force the Pallas kernel through the interpreter
                    (kernel-body validation in CI, 1-2 ulp vs "ref").
    """
    if impl == "interpret":
        return True, True
    if impl == "fused":
        return not _interpret_default(), False
    if impl != "ref":
        raise ValueError(f"unknown server-plane impl {impl!r}")
    return False, False


def mix_coefs(fl, t, *, adaptive: bool = True):
    """(4,) f32 = [alpha0, eta, alpha_cap, t] for ``server_mix_*``.
    ``adaptive=False`` zeroes the schedule (fedavg/fedprox: alpha == 0)."""
    tf = jnp.asarray(t, jnp.float32)
    if not adaptive:
        z = jnp.float32(0.0)
        return jnp.stack([z, z, z, tf])
    return jnp.stack([jnp.float32(fl.alpha0), jnp.float32(fl.eta),
                      jnp.float32(fl.alpha_cap), tf])


# ---------------------------------------------------------------------------
# kernel bodies: load the tile, run the SHARED oracle math, store
# ---------------------------------------------------------------------------

def _mix_kernel(prev_ref, stacked_ref, sizes_ref, keep_ref, coefs_ref,
                out_ref):
    out_ref[...] = ref.server_mix_math(
        prev_ref[...], stacked_ref[...], sizes_ref[...], keep_ref[...],
        coefs_ref[...])


def _mix_delta_kernel(prev_ref, dstacked_ref, rowscale_ref, sizes_ref,
                      keep_ref, coefs_ref, out_ref):
    out_ref[...] = ref.server_mix_delta_math(
        prev_ref[...], dstacked_ref[...], rowscale_ref[...], sizes_ref[...],
        keep_ref[...], coefs_ref[...])


def _mix_scatter_kernel(block, prev_ref, vals_ref, idx_ref, sizes_ref,
                        keep_ref, coefs_ref, out_ref):
    # the tile's global offset: positions outside [start, start+block)
    # are masked inside the shared math, so the scatter composes with
    # the 1-D tiling exactly like the dense accumulation does
    start = pl.program_id(0) * block
    out_ref[...] = ref.server_mix_scatter_math(
        prev_ref[...], vals_ref[...], idx_ref[...], sizes_ref[...],
        keep_ref[...], coefs_ref[...], start=start)


def _async_kernel(prev_ref, stacked_ref, qsum_ref, qgamma_ref, sizes_ref,
                  delayed_ref, delays_ref, tq_ref, hyp_ref,
                  out_ref, qsum_out_ref, qgamma_out_ref):
    out, new_qsum, new_qgamma = ref.server_async_math(
        prev_ref[...], stacked_ref[...], qsum_ref[...], qgamma_ref[...],
        sizes_ref[...], delayed_ref[...], delays_ref[...], tq_ref[...],
        hyp_ref[...])
    out_ref[...] = out
    qsum_out_ref[...] = new_qsum
    qgamma_out_ref[...] = new_qgamma


def _adam_kernel(prev_ref, stacked_ref, m_ref, v_ref, sizes_ref, keep_ref,
                 scalars_ref, out_ref, m_out_ref, v_out_ref):
    out, new_m, new_v = ref.server_adam_math(
        prev_ref[...], stacked_ref[...], m_ref[...], v_ref[...],
        sizes_ref[...], keep_ref[...], scalars_ref[...])
    out_ref[...] = out
    m_out_ref[...] = new_m
    v_out_ref[...] = new_v


# ---------------------------------------------------------------------------
# flat wrappers: pad to the tile grid, one pallas_call, slice back
# ---------------------------------------------------------------------------

def _grid(N: int, block: int) -> tuple[int, int, int]:
    block = min(block, N)
    pad = (-N) % block
    return block, pad, (N + pad) // block


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def server_mix_flat(prev, stacked, sizes, keep, coefs, *,
                    block: int = DEFAULT_BLOCK, interpret: bool = False):
    """prev: (N,); stacked: (K, N); sizes/keep: (K,) f32; coefs: (4,)."""
    (N,) = prev.shape
    K = stacked.shape[0]
    block, pad, n_blocks = _grid(N, block)
    if pad:
        prev = jnp.pad(prev, (0, pad))
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        _mix_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(prev.shape, prev.dtype),
        interpret=interpret,
    )(prev, stacked, sizes, keep, coefs)
    return out[:N] if pad else out


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def server_mix_delta_flat(prev, dstacked, rowscale, sizes, keep, coefs, *,
                          block: int = DEFAULT_BLOCK,
                          interpret: bool = False):
    """Compressed-uplink sync plane: prev (N,); dstacked (K, N) quantized
    deltas (int8 / bf16 / f32); rowscale (K,) f32 dequantization scales;
    sizes/keep (K,) f32; coefs (4,). Dequantize-accumulate fused: the
    int8/bf16 rows upcast INSIDE the kernel tile, so the server's HBM
    pass streams the compressed bytes, not a dense f32 copy."""
    (N,) = prev.shape
    K = dstacked.shape[0]
    block, pad, n_blocks = _grid(N, block)
    if pad:
        prev = jnp.pad(prev, (0, pad))
        dstacked = jnp.pad(dstacked, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        _mix_delta_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(prev.shape, prev.dtype),
        interpret=interpret,
    )(prev, dstacked, rowscale, sizes, keep, coefs)
    return out[:N] if pad else out


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def server_mix_scatter_flat(prev, vals, idx, sizes, keep, coefs, *,
                            block: int = DEFAULT_BLOCK,
                            interpret: bool = False):
    """Top-k sparsified sync plane: prev (N,); vals (K, kk) f32 surviving
    delta values at GLOBAL flat positions idx (K, kk) int32; sizes/keep
    (K,) f32; coefs (4,). Every tile sees the full (K, kk) coordinate
    list (kk << N) and scatters only the in-tile positions."""
    (N,) = prev.shape
    K, kk = vals.shape
    block, pad, n_blocks = _grid(N, block)
    if pad:
        prev = jnp.pad(prev, (0, pad))
    out = pl.pallas_call(
        functools.partial(_mix_scatter_kernel, block),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((K, kk), lambda i: (0, 0)),
            pl.BlockSpec((K, kk), lambda i: (0, 0)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(prev.shape, prev.dtype),
        interpret=interpret,
    )(prev, vals, idx, sizes, keep, coefs)
    return out[:N] if pad else out


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def server_async_flat(prev, stacked, qsum, qgamma, sizes, delayed, delays,
                      tq, hyp, *, block: int = DEFAULT_BLOCK,
                      interpret: bool = False):
    """prev: (N,); stacked: (K, N); qsum: (Q, N) f32; qgamma: (Q,) f32;
    sizes/delayed: (K,) f32; delays: (K,) i32; tq: (2,) i32 = [t, t % Q];
    hyp: (4,) f32 = [alpha0, eta, alpha_cap, staleness_b].
    Returns (out (N,), new_qsum (Q, N) f32, new_qgamma (Q,) f32)."""
    (N,) = prev.shape
    K, Q = stacked.shape[0], qgamma.shape[0]
    block, pad, n_blocks = _grid(N, block)
    if pad:
        prev = jnp.pad(prev, (0, pad))
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        qsum = jnp.pad(qsum, ((0, 0), (0, pad)))
    out, new_qsum, new_qgamma = pl.pallas_call(
        _async_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((Q, block), lambda i: (0, i)),
            pl.BlockSpec((Q,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((Q, block), lambda i: (0, i)),
            pl.BlockSpec((Q,), lambda i: (0,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(prev.shape, prev.dtype),
            jax.ShapeDtypeStruct(qsum.shape, jnp.float32),
            jax.ShapeDtypeStruct((Q,), jnp.float32),
        ),
        interpret=interpret,
    )(prev, stacked, qsum, qgamma, sizes, delayed, delays, tq, hyp)
    if pad:
        return out[:N], new_qsum[:, :N], new_qgamma
    return out, new_qsum, new_qgamma


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def server_adam_flat(prev, stacked, m, v, sizes, keep, scalars, *,
                     block: int = DEFAULT_BLOCK, interpret: bool = False):
    """prev: (N,); stacked: (K, N); m/v: (N,) f32; sizes/keep: (K,) f32;
    scalars: (5,) f32 = [b1, b2, lr, tau, step] (step pre-incremented).
    Returns (out (N,), new_m (N,) f32, new_v (N,) f32)."""
    (N,) = prev.shape
    K = stacked.shape[0]
    block, pad, n_blocks = _grid(N, block)
    if pad:
        prev = jnp.pad(prev, (0, pad))
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        m = jnp.pad(m, (0, pad))
        v = jnp.pad(v, (0, pad))
    out, new_m, new_v = pl.pallas_call(
        _adam_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((5,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(prev.shape, prev.dtype),
            jax.ShapeDtypeStruct(prev.shape, jnp.float32),
            jax.ShapeDtypeStruct(prev.shape, jnp.float32),
        ),
        interpret=interpret,
    )(prev, stacked, m, v, sizes, keep, scalars)
    if pad:
        return out[:N], new_m[:N], new_v[:N]
    return out, new_m, new_v


# ---------------------------------------------------------------------------
# tree drivers: whole param pytree -> one flat vector per dtype group ->
# one kernel call per round per group
# ---------------------------------------------------------------------------

def _dtype_groups(leaves):
    """Leaf indices grouped by dtype, insertion-ordered (usually 1 group)."""
    groups: dict = {}
    for i, x in enumerate(leaves):
        groups.setdefault(jnp.asarray(x).dtype, []).append(i)
    return groups


def _cat(parts):
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)


def _split_back(flat, leaves_like, idxs, out_leaves):
    lead = 1
    for d in flat.shape[:-1]:       # leading (K,)/(Q,) axes, if any
        lead *= d
    off = 0
    for i in idxs:
        n = leaves_like[i].size // lead
        out_leaves[i] = flat[..., off:off + n].reshape(leaves_like[i].shape)
        off += n


def _co_leaves(tree, treedef):
    leaves, td = jax.tree.flatten(tree)
    assert td == treedef, "co-tree structure mismatch"
    return leaves


def server_mix_tree(prev, stacked, sizes, keep, coefs, *, impl: str = "fused",
                    block: int = DEFAULT_BLOCK):
    """Sync server plane over pytrees. ``stacked`` leaves carry a leading
    client axis. ``impl``: see ``_route``.

    The kernel path flattens to one vector per dtype group — ONE
    pallas_call per round per group (flat-staged production params make
    the concat free). The oracle path runs the same single-pass math
    per leaf: inside the round jit that costs no extra dispatch and
    skips the concat/split copies, and per-ELEMENT the op sequence is
    identical either way."""
    kernel, interpret = _route(impl)
    leaves_p, treedef = jax.tree.flatten(prev)
    leaves_s = _co_leaves(stacked, treedef)
    out_leaves = [None] * len(leaves_p)
    if kernel:
        for _, idxs in _dtype_groups(leaves_p).items():
            K = leaves_s[idxs[0]].shape[0]
            fp = _cat([leaves_p[i].reshape(-1) for i in idxs])
            fs = _cat([leaves_s[i].reshape(K, -1) for i in idxs])
            of = server_mix_flat(fp, fs, sizes, keep, coefs, block=block,
                                 interpret=interpret)
            _split_back(of, leaves_p, idxs, out_leaves)
    else:
        for i, (lp, ls) in enumerate(zip(leaves_p, leaves_s)):
            of = ref.server_mix_math(lp.reshape(-1),
                                     ls.reshape(ls.shape[0], -1),
                                     sizes, keep, coefs)
            out_leaves[i] = of.reshape(lp.shape)
    return treedef.unflatten(out_leaves)


def server_mix_compressed_tree(prev, groups, sizes, keep, coefs, *,
                               impl: str = "fused",
                               block: int = DEFAULT_BLOCK):
    """Sync server plane consuming compressed client deltas directly —
    the fused dequantize-accumulate dispatch behind the mix family's
    ``ServerStrategy.compressed_server_update``.

    ``groups`` is the flat per-dtype-group payload list a
    ``repro.comm`` plane emits from ``compress``: ``(leaf_idxs,
    payload)`` pairs where ``payload`` is either
    ``{"kind": "delta", "d": (K, N) int8|bf16, "scale": (K,) f32}``
    (q8 / bf16 planes) or ``{"kind": "topk", "v": (K, kk) f32,
    "i": (K, kk) int32}`` (top-k sparsification). The leaf grouping is
    the SAME ``_dtype_groups(prev leaves)`` split the dense tree
    drivers use, so one kernel call per round per group consumes the
    compressed bytes with no dense intermediate."""
    kernel, interpret = _route(impl)
    leaves_p, treedef = jax.tree.flatten(prev)
    out_leaves = [None] * len(leaves_p)
    for idxs, payload in groups:
        fp = _cat([leaves_p[i].reshape(-1) for i in idxs])
        if payload["kind"] == "topk":
            if kernel:
                of = server_mix_scatter_flat(
                    fp, payload["v"], payload["i"], sizes, keep, coefs,
                    block=block, interpret=interpret)
            else:
                of = ref.server_mix_scatter_math(
                    fp, payload["v"], payload["i"], sizes, keep, coefs)
        elif payload["kind"] == "delta":
            if kernel:
                of = server_mix_delta_flat(
                    fp, payload["d"], payload["scale"], sizes, keep, coefs,
                    block=block, interpret=interpret)
            else:
                of = ref.server_mix_delta_math(
                    fp, payload["d"], payload["scale"], sizes, keep, coefs)
        else:
            raise ValueError(f"unknown payload kind {payload['kind']!r}")
        _split_back(of, leaves_p, idxs, out_leaves)
    return treedef.unflatten(out_leaves)


def server_async_tree(prev, stacked, queue, sizes, delayed, delays, t, hyp,
                      *, impl: str = "fused", block: int = DEFAULT_BLOCK):
    """Async server plane over pytrees: one fused enqueue+pop+mix per
    round. ``queue`` = {"sum": pytree with leading (Q,), "gamma": (Q,)}.
    Returns (new_global, new_queue)."""
    kernel, interpret = _route(impl)
    qgamma = queue["gamma"]
    Q = qgamma.shape[0]
    tq = jnp.stack([jnp.asarray(t, jnp.int32),
                    jnp.asarray(t, jnp.int32) % Q])
    leaves_p, treedef = jax.tree.flatten(prev)
    leaves_s = _co_leaves(stacked, treedef)
    leaves_q = _co_leaves(queue["sum"], treedef)
    out_leaves = [None] * len(leaves_p)
    qs_leaves = [None] * len(leaves_p)
    new_qgamma = qgamma
    if kernel:
        for _, idxs in _dtype_groups(leaves_p).items():
            K = leaves_s[idxs[0]].shape[0]
            fp = _cat([leaves_p[i].reshape(-1) for i in idxs])
            fs = _cat([leaves_s[i].reshape(K, -1) for i in idxs])
            fq = _cat([leaves_q[i].reshape(Q, -1) for i in idxs])
            of, oq, new_qgamma = server_async_flat(
                fp, fs, fq, qgamma, sizes, delayed, delays, tq, hyp,
                block=block, interpret=interpret)
            _split_back(of, leaves_p, idxs, out_leaves)
            _split_back(oq, leaves_q, idxs, qs_leaves)
    else:
        for i, (lp, ls, lq) in enumerate(zip(leaves_p, leaves_s, leaves_q)):
            of, oq, new_qgamma = ref.server_async_math(
                lp.reshape(-1), ls.reshape(ls.shape[0], -1),
                lq.reshape(Q, -1), qgamma, sizes, delayed, delays, tq, hyp)
            out_leaves[i] = of.reshape(lp.shape)
            qs_leaves[i] = oq.reshape(lq.shape)
    return (treedef.unflatten(out_leaves),
            {"sum": treedef.unflatten(qs_leaves), "gamma": new_qgamma})


def server_adam_tree(prev, stacked, m, v, sizes, keep, scalars, *,
                     impl: str = "fused", block: int = DEFAULT_BLOCK):
    """FedOpt server plane over pytrees. ``m``/``v`` are f32 trees shaped
    like ``prev``. Returns (new_global, new_m, new_v)."""
    kernel, interpret = _route(impl)
    leaves_p, treedef = jax.tree.flatten(prev)
    leaves_s = _co_leaves(stacked, treedef)
    leaves_m = _co_leaves(m, treedef)
    leaves_v = _co_leaves(v, treedef)
    out_leaves = [None] * len(leaves_p)
    m_leaves = [None] * len(leaves_p)
    v_leaves = [None] * len(leaves_p)
    if kernel:
        for _, idxs in _dtype_groups(leaves_p).items():
            K = leaves_s[idxs[0]].shape[0]
            fp = _cat([leaves_p[i].reshape(-1) for i in idxs])
            fs = _cat([leaves_s[i].reshape(K, -1) for i in idxs])
            fm = _cat([leaves_m[i].reshape(-1) for i in idxs])
            fv = _cat([leaves_v[i].reshape(-1) for i in idxs])
            of, om, ov = server_adam_flat(fp, fs, fm, fv, sizes, keep,
                                          scalars, block=block,
                                          interpret=interpret)
            _split_back(of, leaves_p, idxs, out_leaves)
            _split_back(om, leaves_m, idxs, m_leaves)
            _split_back(ov, leaves_v, idxs, v_leaves)
    else:
        for i, (lp, ls, lm, lv) in enumerate(
                zip(leaves_p, leaves_s, leaves_m, leaves_v)):
            of, om, ov = ref.server_adam_math(
                lp.reshape(-1), ls.reshape(ls.shape[0], -1),
                lm.reshape(-1), lv.reshape(-1), sizes, keep, scalars)
            out_leaves[i] = of.reshape(lp.shape)
            m_leaves[i] = om.reshape(lm.shape)
            v_leaves[i] = ov.reshape(lv.shape)
    return (treedef.unflatten(out_leaves), treedef.unflatten(m_leaves),
            treedef.unflatten(v_leaves))
