"""jit'd wrappers: the public kernel API used by the rest of the framework.

On CPU (this container) every wrapper runs the Pallas kernel in interpret
mode or falls back to the ref — the TPU path is the pallas_call itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ama_mix import ama_mix_flat
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.server_plane import (server_adam_flat, server_adam_tree,
                                        server_async_flat, server_async_tree,
                                        server_mix_flat, server_mix_tree)

__all__ = ["ama_mix_flat", "flash_attention", "rwkv6_scan",
           "ama_mix_tree", "ama_mix_pairwise",
           "server_mix_flat", "server_async_flat", "server_adam_flat",
           "server_mix_tree", "server_async_tree", "server_adam_tree"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ama_mix_tree(prev_tree, stacked_tree, alpha, weights, *,
                 interpret: bool | None = None):
    """AMA aggregation over whole param pytrees through the fused kernel.

    prev_tree leaves (..., ); stacked_tree leaves (K, ...).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret

    def one(p, s):
        K = s.shape[0]
        flat_p = p.reshape(-1)
        flat_s = s.reshape(K, -1)
        out = ama_mix_flat(flat_p, flat_s, alpha, weights,
                           interpret=interpret)
        return out.reshape(p.shape)

    return jax.tree.map(one, prev_tree, stacked_tree)


def ama_mix_pairwise(prev_tree, agg_tree, alpha, *, interpret=None):
    """alpha*prev + (1-alpha)*agg via the same kernel (K=1)."""
    interpret = (not _on_tpu()) if interpret is None else interpret

    def one(p, g):
        flat_p = p.reshape(-1)
        flat_s = g.reshape(1, -1)
        w = (1.0 - jnp.asarray(alpha, jnp.float32)).reshape(1)
        return ama_mix_flat(flat_p, flat_s, alpha, w,
                            interpret=interpret).reshape(p.shape)

    return jax.tree.map(one, prev_tree, agg_tree)
