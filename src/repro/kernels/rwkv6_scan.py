"""RWKV-6 recurrence kernel (TPU Pallas).

State S: (hd_k, hd_v) per (batch, head). The CUDA wkv6 kernel assigns one
thread per channel; the TPU adaptation instead keeps S resident in VMEM
for a whole sequence CHUNK per grid step and walks time sequentially
inside the kernel — the (hd, hd) outer products and r-contractions are
VPU/MXU work, and sequential-over-time, parallel-over-(B, H) matches the
TPU's grid model (no warp shuffles needed).

Grid: (B*H, S/chunk). The time axis must be the LAST grid dimension: TPU
grid iteration is sequential over the trailing axis, so the VMEM-carried
state (in/out aliased accumulator block) flows chunk to chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_ref,
            *, chunk: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = s0_ref[...]

    u = u_ref[0].astype(jnp.float32)                   # (hd,)
    S = s_ref[0].astype(jnp.float32)                   # (hd, hd)

    def step(t, S):
        r_t = r_ref[0, t].astype(jnp.float32)          # (hd,)
        k_t = k_ref[0, t].astype(jnp.float32)
        v_t = v_ref[0, t].astype(jnp.float32)
        w_t = w_ref[0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]               # (hd, hd)
        y = (r_t[:, None] * (S + u[:, None] * kv)).sum(axis=0)
        y_ref[0, t] = y.astype(y_ref.dtype)
        return w_t[:, None] * S + kv

    S = jax.lax.fori_loop(0, chunk, step, S)
    s_ref[0] = S.astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0, *, chunk: int = 128,
               interpret: bool = False):
    """r/k/v/w: (B, S, H, hd) f32; u: (H, hd); s0: (B, H, hd, hd) f32.

    Returns (y (B, S, H, hd) f32, s_final (B, H, hd, hd) f32).
    """
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)

    def fold(x):        # (B*H, S, hd)
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd).astype(jnp.float32)

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u[None].astype(jnp.float32),
                          (B, H, hd)).reshape(B * H, hd)
    s0f = s0.reshape(B * H, hd, hd).astype(jnp.float32)

    grid = (B * H, S // chunk)
    y, s_fin = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, hd), lambda b, t: (b, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0f)
    y = y.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return y, s_fin.reshape(B, H, hd, hd)
