"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ama_mix_ref(prev, stacked, alpha, weights):
    """alpha*prev + sum_k weights[k]*stacked[k], f32 accumulation.

    prev: (N,) or any shape; stacked: (K, *prev.shape); weights: (K,).
    """
    acc = alpha.astype(jnp.float32) * prev.astype(jnp.float32)
    acc = acc + jnp.einsum(
        "k...,k->...", stacked.astype(jnp.float32), weights.astype(jnp.float32))
    return acc.astype(prev.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Plain softmax attention. q/k/v: (B, S, H, hd) (kv already repeated)."""
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = kpos <= qpos
    if window:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """RWKV-6 recurrence oracle.

    r/k/v/w: (B, S, H, hd) f32 (w in (0,1)); u: (H, hd); s0: (B, H, hd, hd).
    Returns (y (B,S,H,hd), s_final).
    """
    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S_ + u[..., None] * kv)
        S_ = w_t[..., :, None] * S_ + kv
        return S_, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin
