"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

The ``server_*_math`` functions double as the SHARED BODY of the fused
server-plane kernels (``kernels/server_plane.py``): the kernel loads its
block from the refs and calls the same function the oracle calls on the
full arrays. Elementwise math and the sequential client-axis
accumulation are therefore the identical op sequence in both; the
interpret-mode kernels match these oracles to within 1-2 ulp (XLA's
multiply-add contraction is shape-dependent, so strict bit-equality
across different blockings is not guaranteed — the engine's scan==loop
bit-identity instead comes from both paths running the SAME program).
Compiled TPU mode is allclose (XLA may re-associate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ama_mix_ref(prev, stacked, alpha, weights):
    """alpha*prev + sum_k weights[k]*stacked[k], f32 accumulation.

    prev: (N,) or any shape; stacked: (K, *prev.shape); weights: (K,).
    """
    acc = alpha.astype(jnp.float32) * prev.astype(jnp.float32)
    acc = acc + jnp.einsum(
        "k...,k->...", stacked.astype(jnp.float32), weights.astype(jnp.float32))
    return acc.astype(prev.dtype)


# ---------------------------------------------------------------------------
# fused server plane (one HBM pass per round): shared kernel/oracle math
# ---------------------------------------------------------------------------

def _norm_weights(sizes, keep):
    """w_i = |d_i|*keep_i / sum_j |d_j|*keep_j (the FedAvg convention);
    ``keep`` is a {0,1} f32 mask. Returns (w, tot)."""
    w = sizes.astype(jnp.float32) * keep.astype(jnp.float32)
    tot = jnp.sum(w)
    return w / jnp.maximum(tot, 1e-9), tot


def server_mix_math(prev, stacked, sizes, keep, coefs):
    """The sync server plane: staleness/participation weights + weighted
    client accumulation + AMA mix, one pass over the parameter axis.

    prev: (n,); stacked: (K, n); sizes/keep: (K,) f32;
    coefs: (4,) f32 = [alpha0, eta, alpha_cap, t]. alpha_t = min(alpha0 +
    eta*t, cap) computed here, so fedavg/fedprox pass zeros for an
    alpha=0 plain weighted average. When nobody is kept (tot == 0) the
    whole beta budget reverts to the previous model.
    """
    alpha = jnp.minimum(coefs[0] + coefs[1] * coefs[3], coefs[2])
    beta = 1.0 - alpha
    w, tot = _norm_weights(sizes, keep)
    a_eff = jnp.where(tot > 0, alpha, alpha + beta)
    # sequential multiply-add chain over the static client axis: XLA
    # fuses it into ONE pass reading each element once (measurably
    # faster than an einsum contraction on CPU), and the per-element op
    # order is independent of the n-blocking, so the kernel tiles and
    # the whole-array oracle stay bit-identical
    acc = prev.astype(jnp.float32) * a_eff
    for k in range(stacked.shape[0]):
        acc = acc + stacked[k].astype(jnp.float32) * (beta * w[k])
    return acc.astype(prev.dtype)


def server_mix_delta_math(prev, dstacked, rowscale, sizes, keep, coefs):
    """The sync server plane consuming COMPRESSED CLIENT DELTAS: row k of
    ``dstacked`` is client k's quantized delta d_k = x_k - prev (int8 or
    bf16; ``rowscale[k]`` de-quantizes it), and the dequantize-accumulate
    happens inside the one pass:

        out = prev * (a_eff + beta * sum_k w_k)
              + sum_k (beta * w_k * rowscale[k]) * d_k

    — algebraically ``server_mix_math`` with x_k = prev + s_k d_k
    substituted (sum_k w_k is 1 when anybody is kept, 0 otherwise, so
    the tot == 0 round reverts to the previous model exactly as the
    dense plane does).

    prev: (n,); dstacked: (K, n) int8/bf16/f32; rowscale/sizes/keep:
    (K,) f32; coefs: (4,) f32 = [alpha0, eta, alpha_cap, t].
    """
    alpha = jnp.minimum(coefs[0] + coefs[1] * coefs[3], coefs[2])
    beta = 1.0 - alpha
    w, tot = _norm_weights(sizes, keep)
    a_eff = jnp.where(tot > 0, alpha, 1.0)
    acc = prev.astype(jnp.float32) * (a_eff + beta * jnp.sum(w))
    for k in range(dstacked.shape[0]):    # same fused multiply-add chain
        acc = acc + dstacked[k].astype(jnp.float32) * (beta * w[k]
                                                       * rowscale[k])
    return acc.astype(prev.dtype)


def server_mix_scatter_math(prev, vals, idx, sizes, keep, coefs, *,
                            start=0):
    """The sync server plane consuming TOP-K SPARSIFIED client deltas:
    row k keeps its kk largest-magnitude delta elements, shipped as
    (value, flat position) pairs, and the sparse scatter-accumulate
    happens against the dense previous model in one pass (same mix
    algebra as ``server_mix_delta_math``).

    prev: (n,) — one tile of the flat parameter axis whose global
    offset is ``start`` (0 for the whole-array oracle); vals: (K, kk)
    f32; idx: (K, kk) int32 GLOBAL flat positions; sizes/keep: (K,)
    f32; coefs: (4,) f32. Positions outside the tile are masked, so
    tiling over ``start`` reproduces the start=0 oracle exactly.
    """
    n = prev.shape[0]
    alpha = jnp.minimum(coefs[0] + coefs[1] * coefs[3], coefs[2])
    beta = 1.0 - alpha
    w, tot = _norm_weights(sizes, keep)
    a_eff = jnp.where(tot > 0, alpha, 1.0)
    acc = prev.astype(jnp.float32) * (a_eff + beta * jnp.sum(w))
    for k in range(vals.shape[0]):        # one masked scatter per client
        local = idx[k].astype(jnp.int32) - start
        inside = jnp.logical_and(local >= 0, local < n)
        contrib = (vals[k].astype(jnp.float32) * (beta * w[k])
                   * inside.astype(jnp.float32))
        acc = acc.at[jnp.clip(local, 0, n - 1)].add(contrib)
    return acc.astype(prev.dtype)


def server_async_math(prev, stacked, qsum, qgamma, sizes, delayed, delays,
                      tq, hyp):
    """The async server plane (paper Eqs. 6-11) in one pass: staleness
    weights gamma^- from ``delays``, ring-buffer enqueue of this round's
    delayed updates, pop of the slot arriving now, and the
    alpha/beta/gamma mix.

    prev: (n,); stacked: (K, n); qsum: (Q, n) f32; qgamma: (Q,) f32;
    sizes/delayed: (K,) f32; delays: (K,) int32; tq: (2,) int32 =
    [t, t % Q] (the slot precomputed so the modulo is shared with the
    enqueue arrivals); hyp: (4,) f32 = [alpha0, eta, alpha_cap,
    staleness_b]. Returns (out, new_qsum, new_qgamma).
    """
    K, Q = stacked.shape[0], qgamma.shape[0]
    t, pop = tq[0], tq[1]
    alpha_un = 1.0 - jax.nn.sigmoid(1.0)                    # Eq. 9
    g = (hyp[3] * jax.nn.sigmoid(-delays.astype(jnp.float32))
         * delayed.astype(jnp.float32))                     # (K,) gamma^-
    arrival = (t + delays) % Q                              # (K,)
    onehot = (arrival[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (K, Q), 1)
              ).astype(jnp.float32) * g[:, None]            # (K, Q)
    qg = qgamma + jnp.sum(onehot, axis=0)
    sel = (jax.lax.broadcasted_iota(jnp.int32, (1, Q), 1)[0] == pop
           ).astype(jnp.float32)                            # (Q,) pop mask
    stale_gamma = jnp.sum(qg * sel)
    new_qgamma = qg * (1.0 - sel)

    A = jnp.minimum(hyp[0] + hyp[1] * t.astype(jnp.float32), hyp[2])
    beta = 1.0 - A
    denom = alpha_un + stale_gamma
    alpha = alpha_un / denom * A                            # Eq. 10
    gscale = A / denom                                      # Eq. 11
    w, tot = _norm_weights(sizes, 1.0 - delayed.astype(jnp.float32))
    a_eff = jnp.where(tot > 0, alpha, alpha + beta)

    # one sequential pass over the client axis feeds BOTH the on-time
    # aggregate and the ring-buffer enqueue (each client row is read
    # once); the multiply-add chains fuse into a single XLA pass and the
    # per-element op order is blocking-independent (kernel == oracle)
    acc = prev.astype(jnp.float32) * a_eff
    rows = [qsum[q] for q in range(Q)]
    for k in range(K):
        x = stacked[k].astype(jnp.float32)
        acc = acc + x * (beta * w[k])
        for q in range(Q):                  # enqueue into arrival slots
            rows[q] = rows[q] + x * onehot[k, q]
    stale = rows[0] * sel[0]                # pop slot t % Q ...
    for q in range(1, Q):
        stale = stale + rows[q] * sel[q]
    acc = acc + stale * gscale
    new_qsum = jnp.stack([rows[q] * (1.0 - sel[q]) for q in range(Q)])
    return acc.astype(prev.dtype), new_qsum, new_qgamma


def server_adam_math(prev, stacked, m, v, sizes, keep, scalars):
    """The FedOpt server plane: weighted pseudo-gradient + one server-Adam
    moment update + the model step, one pass.

    prev: (n,); stacked: (K, n); m/v: (n,) f32; sizes/keep: (K,) f32;
    scalars: (5,) f32 = [b1, b2, lr, tau, step] (step ALREADY
    incremented). Returns (out, new_m, new_v).
    """
    b1, b2, lr, tau, step = (scalars[i] for i in range(5))
    w, tot = _norm_weights(sizes, keep)
    agg = jnp.zeros_like(prev, jnp.float32)
    for k in range(stacked.shape[0]):       # same fused-chain pattern as
        agg = agg + stacked[k].astype(jnp.float32) * w[k]    # server_mix
    p32 = prev.astype(jnp.float32)
    delta = jnp.where(tot > 0, agg - p32, 0.0)
    new_m = b1 * m + (1.0 - b1) * delta
    new_v = b2 * v + (1.0 - b2) * delta * delta
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    update = (new_m / bc1) / (jnp.sqrt(new_v / bc2) + tau)
    return (p32 + lr * update).astype(prev.dtype), new_m, new_v


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Plain softmax attention. q/k/v: (B, S, H, hd) (kv already repeated)."""
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = kpos <= qpos
    if window:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """RWKV-6 recurrence oracle.

    r/k/v/w: (B, S, H, hd) f32 (w in (0,1)); u: (H, hd); s0: (B, H, hd, hd).
    Returns (y (B,S,H,hd), s_final).
    """
    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S_ + u[..., None] * kv)
        S_ = w_t[..., :, None] * S_ + kv
        return S_, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin
