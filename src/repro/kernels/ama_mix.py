"""Fused AMA parameter-mix kernel (the paper's server-side hot loop).

Computes  out = alpha * prev + sum_k weights[k] * stacked[k]  over a flat
parameter vector. At LLM scale this is purely HBM-bandwidth-bound:
(K+1) streams in, 1 stream out. The fused kernel reads each element once
and accumulates in VREGs, instead of K materialised intermediates
(jnp would need K-1 temporaries or an (K, N) einsum reduction buffer).

Grid: 1-D over N/block tiles. Block shape (block,) with block a multiple
of 1024 (=8 sublanes x 128 lanes of f32) keeps the VPU fully fed; the K
stacked rows of a tile are staged through VMEM one at a time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 1024


def _kernel(prev_ref, stacked_ref, alpha_ref, w_ref, out_ref, *, K: int):
    a = alpha_ref[0]
    acc = prev_ref[...].astype(jnp.float32) * a
    for kk in range(K):                       # static unroll over clients
        acc += stacked_ref[kk, :].astype(jnp.float32) * w_ref[kk]
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ama_mix_flat(prev, stacked, alpha, weights, *, block: int = DEFAULT_BLOCK,
                 interpret: bool = False):
    """prev: (N,); stacked: (K, N); alpha: scalar; weights: (K,)."""
    (N,) = prev.shape
    K = stacked.shape[0]
    block = min(block, N)
    pad = (-N) % block
    if pad:
        prev = jnp.pad(prev, (0, pad))
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    n_blocks = prev.shape[0] // block
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    weights = weights.astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, K=K),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(prev.shape, prev.dtype),
        interpret=interpret,
    )(prev, stacked, alpha, weights)
    return out[:N] if pad else out
