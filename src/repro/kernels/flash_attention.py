"""Block-tiled online-softmax attention (TPU Pallas).

Causal (optionally sliding-window) flash attention with MXU-aligned
128x128 tiles. Grid (B*H, n_q_blocks); the kernel loops over KV blocks up
to the causal frontier with VMEM-resident (m, l, acc) accumulators.

TPU adaptation notes (vs. the CUDA flash-attention algorithm): block
shapes are chosen for the 128x128 MXU and 8x128 VPU registers rather than
warps; the KV loop is a sequential fori inside one grid step (no
cross-core shuffle reductions — each (batch, head, q-block) owns its
whole softmax row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, *, scale: float, causal: bool,
            window: int, block_q: int, block_k: int, seq_len: int):
    qi = pl.program_id(1)
    # NOTE: size-1 pl.ds slices (not bare int indices) throughout — int
    # indices break interpret-mode state discharge on this JAX version.
    q = pl.load(q_ref, (pl.ds(0, 1), slice(None), slice(None)))
    q = q[0].astype(jnp.float32) * scale              # (block_q, hd)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc0 = jnp.zeros((block_q, q.shape[-1]), jnp.float32)

    n_k = seq_len // block_k
    if causal:
        # only KV blocks up to the causal frontier of this q block (and,
        # with a window, only blocks inside it): saves ~2x / ~S/window FLOPs
        hi = pl.cdiv((qi + 1) * block_q, block_k)
        n_k = jnp.minimum(n_k, hi)
    lo = 0
    if window:
        lo = jnp.maximum(0, (qi * block_q - window) // block_k)

    def body(ki, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.ds(0, 1), pl.dslice(ki * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.ds(0, 1), pl.dslice(ki * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                    # (block_q, block_k)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask = k_pos <= q_pos
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(lo, n_k, body, (m0, l0, acc0))
    out = (acc / jnp.maximum(l, 1e-30)).astype(out_ref.dtype)
    pl.store(out_ref, (pl.ds(0, 1), slice(None), slice(None)), out[None])


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q/k/v: (B, S, H, hd), kv already head-repeated. Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = hd ** -0.5

    # (B*H, S, hd) layout: one grid row per (batch, head)
    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    qf, kf, vf = fold(q), fold(k), fold(v)
    grid = (B * H, S // block_q)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, seq_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
