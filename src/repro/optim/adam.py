"""Adam / AdamW in pure JAX."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0):
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v
                         + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m, v, p=None):
            step = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                step = step - lr * weight_decay * p.astype(jnp.float32)
            return step

        if weight_decay and params is not None:
            updates = jax.tree.map(upd, m, v, params)
        else:
            updates = jax.tree.map(upd, m, v)
        return updates, {"m": m, "v": v, "t": t}

    return init, update
