from repro.optim.sgd import sgd, apply_updates
from repro.optim.adam import adam
from repro.optim.masked import apply_mask, masked_update
