"""SGD (optionally with momentum). Minimal optax-like (init, update) pair."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd(lr: float, momentum: float = 0.0):
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        del params
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g, grads), state
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        return jax.tree.map(lambda m: -lr * m, new_m), new_m

    return init, update


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
