"""Gradient masking utilities (FES, Eq. 3: frozen feature extractor)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_mask(grads, mask):
    """Zero grads where mask is False. mask mirrors grads' structure."""
    return jax.tree.map(
        lambda g, m: g * jnp.asarray(m, g.dtype), grads, mask)


def masked_update(grads, mask, limited):
    """Per-cohort dynamic FES: if ``limited`` (traced bool), keep only
    classifier grads; else keep all."""
    return jax.tree.map(
        lambda g, m: jnp.where(limited, g * jnp.asarray(m, g.dtype), g),
        grads, mask)
