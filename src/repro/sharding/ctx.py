"""Mesh-context-aware sharding constraints.

Model code is mesh-agnostic; ``constrain`` applies a
with_sharding_constraint only when a mesh with the named axes is active
and every named dim divides its axis — otherwise it is a no-op (CPU
tests, reduced configs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _active_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:  # `with mesh:` context managers set the thread-resources env
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    return None


def mesh_axis_names() -> tuple:
    m = _active_mesh()
    return tuple(m.axis_names) if m is not None else ()


def constrain(x, *axes):
    """axes: one entry per dim of x — mesh axis name or None."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    fixed = []
    for ax, dim in zip(axes, x.shape):
        if ax is None or ax not in mesh.axis_names:
            fixed.append(None)
            continue
        size = mesh.shape[ax]
        fixed.append(ax if size and dim % size == 0 else None)
    if not any(fixed):
        return x
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def axis_size(name: str) -> int:
    """Size of a mesh axis in the ACTIVE mesh context (1 when no mesh is
    active or the axis doesn't exist) — how the round engine decides at
    trace time whether the client axis is actually distributed."""
    mesh = _active_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return int(mesh.shape[name])


def reduce_leading(tree, weights):
    """Weighted sum over every leaf's LEADING (client) axis, f32.

    weights (C,) -> leaf (C, ...) contracts to (...); weights (C, R) ->
    (R, ...) (R simultaneous reductions — e.g. the async plane's on-time
    aggregate + Q ring-buffer enqueue slots in one contraction). The
    input is constrained onto the mesh's "client" axis first, so on a
    sharded mesh XLA lowers this as a LOCAL partial sum followed by one
    N-byte (or R x N) all-reduce — the per-round collective moves the
    model size, not cohorts x model size.
    """
    w = weights.astype(jnp.float32)
    eq = "c...,cr->r..." if w.ndim == 2 else "c...,c->..."

    def red(x):
        if not getattr(x, "ndim", 0):
            return x
        xc = constrain(x, "client", *([None] * (x.ndim - 1)))
        return jnp.einsum(eq, xc.astype(jnp.float32), w)

    return jax.tree.map(red, tree)


def constrain_leading(tree, axis: str):
    """Constrain every leaf of a pytree on its LEADING dim only.

    The engine uses this on the stacked client axis: batches
    (C, steps, b, ...) and stacked client params (C, ...) shard over the
    FL mesh's "client" axis while the trailing dims stay unconstrained
    (FSDP/TP constraints belong to the model code). No-op leaf-wise when
    no mesh is active or the axis doesn't divide (CPU tests)."""
    return jax.tree.map(
        lambda x: constrain(x, axis, *([None] * (x.ndim - 1)))
        if getattr(x, "ndim", 0) else x, tree)
