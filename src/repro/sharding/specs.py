"""PartitionSpec derivation for every param/input leaf.

Two mesh contexts:
  * TRAIN (federated round): axes ("client", "dsub", "model") — client
    cohorts x FSDP x tensor-parallel. Global params have no client axis
    (replicated across cohorts until the broadcast inside the round).
  * SERVE: axes ("data", "model") — batch x tensor-parallel
    (+ optional 2-D weight sharding for >=100B archs: second weight dim
    on "data").

Rules are by param role (path name + ndim); any mesh axis that does not
divide the dim is dropped (validated against the actual mesh), so the same
rules serve reduced smoke configs and the 512-chip production mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def _fit(spec_axes, shape, mesh):
    """Drop axes that don't divide the corresponding dim (tuple axes =
    sharding over the product of mesh axes)."""
    fixed = []
    for ax, dim in zip(spec_axes, shape):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if not axes or dim % size != 0:
            fixed.append(None)
        elif len(axes) == 1:
            fixed.append(axes[0])
        else:
            fixed.append(axes)
    return P(*fixed)


# --------------------------------------------------------- param rules -----

def _param_axes(names: list[str], ndim: int, cfg, *, fsdp, tp):
    """Returns a per-dim axis list for the *unstacked* param shape."""
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    ctx = set(names)

    # --- embeddings / head
    if "embed" in ctx and name == "table":
        return [tp, fsdp]
    if parent == "lm_head" and name == "w":
        return [fsdp, tp]
    if parent == "vision_proj" and name == "w":
        return [fsdp, tp]
    if name == "enc_pos":
        return [None, None]

    # --- MoE experts (E, d, f) / (E, f, d); router replicated.
    # Three regimes (§Perf H1):
    #  * factorized mesh ("expert","etp"): E on "expert", f on "etp" —
    #    the textbook expert-parallel + within-expert-TP layout;
    #  * E divides the model axis: pure expert-parallel on "model";
    #  * otherwise: TP within each expert (f on "model") — leaving E
    #    unsharded with nothing on "model" makes GSPMD compute every
    #    expert FFN redundantly on all model shards (9x waste).
    if parent == "moe" or "moe" in ctx:
        factorized = tp == ("expert", "etp")
        if factorized:
            if name in ("w_in", "w_gate") and ndim >= 3:
                return ["expert", fsdp, "etp"]
            if name == "w_out" and ndim >= 3:
                return ["expert", "etp", fsdp]
            return [None] * ndim
        ep = bool(cfg.num_experts) and cfg.num_experts % 16 == 0
        if name in ("w_in", "w_gate") and ndim >= 3:
            return [tp, fsdp, None] if ep else [None, fsdp, tp]
        if name == "w_out" and ndim >= 3:
            return [tp, None, fsdp] if ep else [None, tp, fsdp]
        return [None] * ndim

    # --- attention projections
    if name in ("wq", "wk", "wv", "wg", "wr") or (
            parent in ("wq", "wk", "wv", "wg", "wr") and name in ("w", "b")):
        if name == "b" or ndim == 1:
            return [tp]
        return [fsdp, tp]
    if name == "wo" or (parent == "wo" and name == "w"):
        if ndim == 1:
            return [None]
        return [tp, fsdp]

    # --- MLP
    if name in ("w_in", "w_gate", "ck") or (
            parent in ("w_in", "w_gate", "ck") and name == "w"):
        return [fsdp, tp] if ndim == 2 else [tp]
    if name in ("w_out", "cv") or (parent in ("w_out", "cv") and name == "w"):
        return [tp, fsdp] if ndim == 2 else [None]
    if name == "cr" or (parent == "cr" and name == "w"):
        return [fsdp, tp] if ndim == 2 else [tp]

    # --- rwkv decay lora / mamba
    if name in ("wA",) or (parent == "wA" and name == "w"):
        return [fsdp, None] if ndim == 2 else [None]
    if name in ("wB",) or (parent == "wB" and name == "w"):
        return [None, tp] if ndim == 2 else [tp]
    if name == "conv":
        return [None, tp]

    # --- everything else (norms, scalars, biases, cnn) replicated
    return [None] * ndim


STACKED_PREFIXES = ("body", "tail", "encoder")


def param_spec(path, leaf, cfg, mesh, *, train: bool):
    names = _path_names(path)
    ndim = leaf.ndim
    stacked = 1 if (names and names[0] in STACKED_PREFIXES) else 0
    # factorized expert mesh: dense params shard over the whole
    # ("expert","etp") tuple == the model axis
    tp = ("expert", "etp") if "expert" in mesh.axis_names else "model"
    if train:
        fsdp = "dsub" if cfg.train_fsdp else None
    else:
        fsdp = "data" if cfg.serve_2d else None
    axes = _param_axes(names, ndim - stacked, cfg, fsdp=fsdp, tp=tp)
    axes = [None] * stacked + axes
    if len(axes) != ndim:           # defensive: replicate on rule mismatch
        axes = [None] * ndim
    return _fit(axes, leaf.shape, mesh)


def params_shardings(params_like, cfg, mesh, *, train: bool,
                     extra_leading: int = 0):
    """NamedShardings for a param tree; extra_leading prepends replicated
    dims (e.g. the async queue's ring axis)."""
    def one(path, leaf):
        sp = param_spec(path, leaf, cfg, mesh, train=train)
        if extra_leading:
            sp = P(*([None] * extra_leading + list(sp)))
        return NamedSharding(mesh, sp)
    return jax.tree_util.tree_map_with_path(one, params_like)


# --------------------------------------------------------- input rules -----

def batch_shardings(batch_like, mesh, *, train: bool):
    """train batches: (C, steps, b, ...) -> client x dsub.
    serve batches:    (B, ...)          -> data."""
    def one(path, leaf):
        if train:
            axes = ["client", None, "dsub"] + [None] * (leaf.ndim - 3)
        else:
            axes = ["data"] + [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, _fit(axes[: leaf.ndim], leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, batch_like)


def sched_shardings(sched_like, mesh):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, _fit(["client"], x.shape, mesh)),
        sched_like)


def cache_shardings(cache_like, cfg, mesh):
    """Decode cache: shard batch dim on "data", trailing feature dims on
    "model" where divisible. Layer-stacked leading dims replicated.

    Leaf shapes seen here:
      kv cache  (L, B, S, KH, hd)   pos (L, B, S)
      rwkv wkv  (L, B, H, hd, hd)   x_tm/x_cm (L, B, d)
      mamba ssm (L, B, H, P, N)     conv (L, B, W-1, C)
      cross-kv  (L, B, Se, KH, hd)
    """
    tp = ("expert", "etp") if "expert" in mesh.axis_names else "model"

    def one(path, leaf):
        names = _path_names(path)
        nd = leaf.ndim
        axes = [None] * nd
        if nd >= 2:
            axes[1] = "data"                       # batch dim
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            last = names[-1] if names else ""
            if nd >= 5:                            # (L,B,S,KH,hd)-likes
                axes[-1] = tp
            elif nd >= 3 and last in ("x_tm", "x_cm", "conv"):
                axes[-1] = tp
        return NamedSharding(mesh, _fit(axes, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_like)


def replicated(tree_like, mesh):
    return jax.tree.map(lambda x: NamedSharding(mesh, P()), tree_like)
