"""The Environment interface and the name-keyed environment registry.

The paper's second challenge — a time-varying wireless environment — is
data, not code: every scenario reduces to per-round schedule arrays the
compiled round consumes unchanged. An ``Environment`` packages the three
places a learning environment can differ:

  * ``Participation`` — which m of K clients take part in round t
    (uniform sampling, availability windows, ...);
  * ``DeviceProfile`` — per-client compute tier, FES limited-ness,
    local-step budget and data size (the paper's FIXED computing-limited
    subset is the default profile);
  * ``ChannelModel`` — per-client upload delay/dropout for round t
    (i.i.d. Bernoulli, bursty two-state Markov fading, SNR/bandwidth
    draws against a round deadline, ...).

Every environment emits the same ``RoundSchedule`` per round and the
same stacked ``{selected, limited, delayed, delays, data_sizes}`` arrays
via ``batch(t0, n_rounds)``, so the ``FederatedSimulation`` paper path,
the jitted pod round and the fused ``lax.scan`` engine consume any
scenario without edits.

THE CONTRACT (the scan engine rides on it): ``batch(t0, n)`` row ``i``
is BIT-IDENTICAL to ``round(t0 + i)``. Round t's schedule must therefore
be a pure function of (config, t) — per-round RNG streams are keyed on
the absolute round index, and stateful channels (Markov chains) memoize
a state trajectory that is itself a pure function of (seed, t). The
property test in ``tests/test_env.py`` enforces this for every
registered environment.

Adding an environment is one file: subclass ``Environment``, decorate it
with ``@register``, import it from ``env/__init__.py`` — it becomes
reachable from every entry point (``FLConfig(env=...)``, ``--env`` on
the launcher, the scenario registry) with no dispatch chain to edit.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import FLConfig


@dataclass
class RoundSchedule:
    """One round's environment draw (the schedule contract)."""

    selected: np.ndarray     # (m,) int32 client indices
    limited: np.ndarray      # (m,) bool — computing-limited (FES) clients
    delayed: np.ndarray      # (m,) bool — upload delayed
    delays: np.ndarray       # (m,) int32 in [1, max_delay] (1 where on time)
    data_sizes: np.ndarray   # (m,) float32 — |D_i| aggregation weights


def round_rng(fl: FLConfig, t: int) -> np.random.RandomState:
    """The per-round schedule RNG stream (seed algorithm, unchanged):
    each round owns an independent stream keyed on its absolute index."""
    return np.random.RandomState((fl.seed * 1_000_003 + t) % 2**32)


def side_rng(fl: FLConfig, t: int) -> np.random.RandomState:
    """A second per-round stream (channel-state chains, trace synthesis)
    that cannot collide with ``round_rng`` draws for the same round."""
    return np.random.RandomState(
        (fl.seed * 1_000_003 + t + 0x9E3779B9) % 2**32)


# ---------------------------------------------------------------------------
# components
# ---------------------------------------------------------------------------
class Participation:
    """Which clients take part in round t. ``select`` draws from the
    round's shared RNG stream FIRST (before the channel), preserving the
    seed's draw order."""

    def __init__(self, fl: FLConfig):
        self.fl = fl

    def select(self, t: int, rng: np.random.RandomState) -> np.ndarray:
        raise NotImplementedError


class UniformParticipation(Participation):
    """m of K uniformly without replacement (paper §V)."""

    def select(self, t, rng):
        return rng.choice(self.fl.num_clients, size=self.fl.clients_per_round,
                          replace=False).astype(np.int32)


class DeviceProfile:
    """Per-client static device facts: compute tier, FES limited-ness,
    local-step budget, dataset size (aggregation weight)."""

    def __init__(self, fl: FLConfig, data_sizes: np.ndarray | None = None):
        self.fl = fl
        self.has_sizes = data_sizes is not None
        self._sizes = (None if data_sizes is None
                       else np.asarray(data_sizes, np.float32))

    def limited(self, selected: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def tier(self, selected: np.ndarray) -> np.ndarray:
        """Compute tier per selected client (0 = limited, 1 = full)."""
        return np.where(self.limited(selected), 0, 1).astype(np.int32)

    def step_budget(self, n_steps: int, selected: np.ndarray) -> np.ndarray:
        """Local-step budget per selected client: limited devices afford
        only a ``fedprox_partial`` fraction of the full step count."""
        full = np.full(len(selected), n_steps, np.int32)
        part = np.maximum(1, (n_steps * self.fl.fedprox_partial)).astype(
            np.int32)
        return np.where(self.limited(selected), part, full)

    def sizes(self, selected: np.ndarray) -> np.ndarray:
        if self._sizes is None:
            return np.ones(len(selected), np.float32)
        return self._sizes[selected].astype(np.float32)


class FixedTierProfile(DeviceProfile):
    """The paper's setting: a FIXED subset of devices (ratio p_limited,
    drawn once from the seed) *is* computing-limited."""

    def __init__(self, fl: FLConfig, data_sizes=None):
        super().__init__(fl, data_sizes)
        rng = np.random.RandomState(fl.seed)
        k = int(round(fl.p_limited * fl.num_clients))
        self.limited_set = set(
            rng.choice(fl.num_clients, size=k, replace=False).tolist())

    def limited(self, selected):
        return np.array([i in self.limited_set for i in selected])


class ChannelModel:
    """Per-client upload delay for round t. ``draw`` consumes the
    round's shared RNG stream AFTER participation, preserving the seed's
    draw order; stateful channels key any extra streams on the absolute
    round index (``side_rng``) so purity in t survives."""

    def __init__(self, fl: FLConfig):
        self.fl = fl

    def draw(self, t: int, selected: np.ndarray,
             rng: np.random.RandomState) -> tuple[np.ndarray, np.ndarray]:
        """-> (delayed (m,) bool, delays (m,) int32 in [1, max_delay])."""
        raise NotImplementedError

    def _no_delays(self, m: int) -> tuple[np.ndarray, np.ndarray]:
        return np.zeros(m, bool), np.ones(m, np.int32)


# ---------------------------------------------------------------------------
# the environment = participation x devices x channel
# ---------------------------------------------------------------------------
class Environment:
    """Base environment: composes the three components with the shared
    per-round RNG stream. Subclasses usually only override
    ``_make_channel``; trace replay overrides ``round`` wholesale."""

    #: registry key; aliases are extra names resolving to the same class
    name: str = ""
    aliases: tuple[str, ...] = ()

    def __init__(self, fl: FLConfig, data_sizes: np.ndarray | None = None):
        self.fl = fl
        self.participation = self._make_participation(fl)
        self.devices = self._make_devices(fl, data_sizes)
        self.channel = self._make_channel(fl)

    # component factories ------------------------------------------------
    def _make_participation(self, fl) -> Participation:
        return UniformParticipation(fl)

    def _make_devices(self, fl, data_sizes) -> DeviceProfile:
        return FixedTierProfile(fl, data_sizes)

    def _make_channel(self, fl) -> ChannelModel:
        raise NotImplementedError

    # the schedule contract ----------------------------------------------
    def round(self, t: int) -> RoundSchedule:
        """Round t's schedule — a pure function of (config, t)."""
        rng = round_rng(self.fl, t)
        sel = self.participation.select(t, rng)
        limited = self.devices.limited(sel)
        delayed, delays = self.channel.draw(t, sel, rng)
        return RoundSchedule(sel, limited, delayed, delays,
                             self.devices.sizes(sel))

    def batch(self, t0: int, n_rounds: int) -> dict[str, np.ndarray]:
        """Stacked (n_rounds, m) schedule arrays for the fused scan
        engine. Row i is BIT-IDENTICAL to ``round(t0 + i)`` — see the
        module docstring; the vectorisation is the output layout, not
        the draws."""
        rows = [self.round(t0 + i) for i in range(n_rounds)]
        return {"selected": np.stack([r.selected for r in rows]),
                "limited": np.stack([r.limited for r in rows]),
                "delayed": np.stack([r.delayed for r in rows]),
                "delays": np.stack([r.delays for r in rows]),
                "data_sizes": np.stack([r.data_sizes for r in rows])}


# ---------------------------------------------------------------------------
# registry (mirrors repro.core.strategies)
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, type[Environment]] = {}


def register(cls: type[Environment]) -> type[Environment]:
    """Class decorator: file-local registration under name + aliases."""
    assert cls.name, cls
    for key in (cls.name,) + tuple(cls.aliases):
        assert key not in _REGISTRY or _REGISTRY[key] is cls, key
        _REGISTRY[key] = cls
    return cls


def names() -> list[str]:
    """All registered environment names (aliases included), sorted."""
    return sorted(_REGISTRY)


def get(name: str) -> type[Environment]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown environment {name!r}; "
                       f"registered: {names()}") from None


def resolve(fl: FLConfig,
            data_sizes: np.ndarray | None = None) -> Environment:
    """Instantiate the environment for a config (``fl.env``)."""
    return get(fl.env)(fl, data_sizes=data_sizes)
