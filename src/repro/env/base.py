"""The Environment interface and the name-keyed environment registry.

The paper's second challenge — a time-varying wireless environment — is
data, not code: every scenario reduces to per-round schedule arrays the
compiled round consumes unchanged. An ``Environment`` packages the three
places a learning environment can differ:

  * ``Participation`` — which m of K clients take part in round t
    (uniform sampling, availability windows, ...);
  * ``DeviceProfile`` — per-client compute tier, FES limited-ness,
    local-step budget and data size (the paper's FIXED computing-limited
    subset is the default profile);
  * ``ChannelModel`` — per-client upload delay/dropout for round t
    (i.i.d. Bernoulli, bursty two-state Markov fading, SNR/bandwidth
    draws against a round deadline, ...).

Every environment emits the same ``RoundSchedule`` per round and the
same stacked ``{selected, limited, delayed, delays, data_sizes}`` arrays
via ``batch(t0, n_rounds)``, so the ``FederatedSimulation`` paper path,
the jitted pod round and the fused ``lax.scan`` engine consume any
scenario without edits.

THE CONTRACT (the scan engine rides on it): ``batch(t0, n)`` row ``i``
is BIT-IDENTICAL to ``round(t0 + i)``. Round t's schedule must therefore
be a pure function of (config, t) — per-round RNG streams are keyed on
the absolute round index, and stateful channels (Markov chains) memoize
a state trajectory that is itself a pure function of (seed, t). The
property test in ``tests/test_env.py`` enforces this for every
registered environment.

Adding an environment is one file: subclass ``Environment``, decorate it
with ``@register``, import it from ``env/__init__.py`` — it becomes
reachable from every entry point (``FLConfig(env=...)``, ``--env`` on
the launcher, the scenario registry) with no dispatch chain to edit.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import FLConfig
from repro.env.virtual import (DENSE_SELECT_MAX, TAG_LIMITED, floyd_sample,
                               hash_u01, is_virtual, select_batch_hashed)


@dataclass
class RoundSchedule:
    """One round's environment draw (the schedule contract)."""

    selected: np.ndarray     # (m,) int32 client indices
    limited: np.ndarray      # (m,) bool — computing-limited (FES) clients
    delayed: np.ndarray      # (m,) bool — upload delayed
    delays: np.ndarray       # (m,) int32 in [1, max_delay] (1 where on time)
    data_sizes: np.ndarray   # (m,) float32 — |D_i| aggregation weights


def round_rng(fl: FLConfig, t: int) -> np.random.RandomState:
    """The per-round schedule RNG stream (seed algorithm, unchanged):
    each round owns an independent stream keyed on its absolute index."""
    return np.random.RandomState((fl.seed * 1_000_003 + t) % 2**32)


def side_rng(fl: FLConfig, t: int) -> np.random.RandomState:
    """A second per-round stream (channel-state chains, trace synthesis)
    that cannot collide with ``round_rng`` draws for the same round."""
    return np.random.RandomState(
        (fl.seed * 1_000_003 + t + 0x9E3779B9) % 2**32)


# ---------------------------------------------------------------------------
# components
# ---------------------------------------------------------------------------
class Participation:
    """Which clients take part in round t. ``select`` draws from the
    round's shared RNG stream FIRST (before the channel), preserving the
    seed's draw order."""

    def __init__(self, fl: FLConfig):
        self.fl = fl

    def select(self, t: int, rng: np.random.RandomState) -> np.ndarray:
        raise NotImplementedError


class UniformParticipation(Participation):
    """m of K uniformly without replacement (paper §V).

    ``rng.choice(K, m, replace=False)`` materialises an O(K) permutation
    per round; beyond ``DENSE_SELECT_MAX`` clients an O(m) Floyd draw
    from the SAME per-round stream takes over. The guard keeps the draw
    sequence (and the bernoulli env's bit-identity net) untouched at
    paper scale."""

    def select(self, t, rng):
        K, m = self.fl.num_clients, self.fl.clients_per_round
        if K <= DENSE_SELECT_MAX:
            return rng.choice(K, size=m, replace=False).astype(np.int32)
        return floyd_sample(rng, K, m)


class DeviceProfile:
    """Per-client static device facts: compute tier, FES limited-ness,
    local-step budget, dataset size (aggregation weight)."""

    def __init__(self, fl: FLConfig, data_sizes=None):
        self.fl = fl
        self.has_sizes = data_sizes is not None
        # data_sizes may be a dense (K,) array OR a callable mapping a
        # client-id array to sizes (virtual populations never hold K
        # floats; VirtualClientShards.client_sizes is the usual source)
        self._sizes_fn = data_sizes if callable(data_sizes) else None
        self._sizes = (None if data_sizes is None or callable(data_sizes)
                       else np.asarray(data_sizes, np.float32))

    def limited(self, selected: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def tier(self, selected: np.ndarray) -> np.ndarray:
        """Compute tier per selected client (0 = limited, 1 = full)."""
        return np.where(self.limited(selected), 0, 1).astype(np.int32)

    def step_budget(self, n_steps: int, selected: np.ndarray) -> np.ndarray:
        """Local-step budget per selected client: limited devices afford
        only a ``fedprox_partial`` fraction of the full step count."""
        full = np.full(len(selected), n_steps, np.int32)
        part = np.maximum(1, (n_steps * self.fl.fedprox_partial)).astype(
            np.int32)
        return np.where(self.limited(selected), part, full)

    def sizes(self, selected: np.ndarray) -> np.ndarray:
        if self._sizes_fn is not None:
            return np.asarray(self._sizes_fn(selected), np.float32)
        if self._sizes is None:
            return np.ones(np.shape(selected), np.float32)
        return self._sizes[selected].astype(np.float32)


class FixedTierProfile(DeviceProfile):
    """The paper's setting: a FIXED subset of devices (ratio p_limited,
    drawn once from the seed) *is* computing-limited."""

    def __init__(self, fl: FLConfig, data_sizes=None):
        super().__init__(fl, data_sizes)
        rng = np.random.RandomState(fl.seed)
        k = int(round(fl.p_limited * fl.num_clients))
        self.limited_set = set(
            rng.choice(fl.num_clients, size=k, replace=False).tolist())

    def limited(self, selected):
        return np.array([i in self.limited_set for i in selected])


class VirtualTierProfile(DeviceProfile):
    """K-free tier profile: limited-ness is a per-client hashed
    Bernoulli(p_limited) coin, evaluated only for selected clients.
    Population-level limited count is Binomial(K, p) rather than the
    dense profile's exact round(p*K) — equal in expectation, and the
    dense profile stays in force below ``VIRTUAL_K_MIN``. All methods
    are shape-generic so a whole (n_rounds, m) block evaluates at once.
    """

    def limited(self, selected):
        return hash_u01(self.fl.seed, TAG_LIMITED,
                        np.asarray(selected)) < self.fl.p_limited

    def step_budget(self, n_steps, selected):
        full = np.full(np.shape(selected), n_steps, np.int32)
        part = np.maximum(1, (n_steps * self.fl.fedprox_partial)).astype(
            np.int32)
        return np.where(self.limited(selected), part, full)


class ChannelModel:
    """Per-client upload delay for round t. ``draw`` consumes the
    round's shared RNG stream AFTER participation, preserving the seed's
    draw order; stateful channels key any extra streams on the absolute
    round index (``side_rng``) so purity in t survives."""

    def __init__(self, fl: FLConfig):
        self.fl = fl

    def draw(self, t: int, selected: np.ndarray,
             rng: np.random.RandomState) -> tuple[np.ndarray, np.ndarray]:
        """-> (delayed (m,) bool, delays (m,) int32 in [1, max_delay])."""
        raise NotImplementedError

    def _no_delays(self, m: int) -> tuple[np.ndarray, np.ndarray]:
        return np.zeros(m, bool), np.ones(m, np.int32)

    def draw_batch(self, t0: int, selected: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Virtual-path draw for a stacked (n_rounds, m) cohort block.

        Default: one ``draw`` per row against a FRESH per-round stream —
        hashed selection consumes no RNG, so the stream starts at
        position 0 (a different stream universe from the dense path,
        which is the point of the ``is_virtual`` guard); still pure in t
        per row. Channels with vectorised hashed draws override this to
        evaluate the whole block at once."""
        rows = [self.draw(t0 + i, selected[i], round_rng(self.fl, t0 + i))
                for i in range(len(selected))]
        return (np.stack([r[0] for r in rows]),
                np.stack([r[1] for r in rows]))


# ---------------------------------------------------------------------------
# the environment = participation x devices x channel
# ---------------------------------------------------------------------------
class Environment:
    """Base environment: composes the three components with the shared
    per-round RNG stream. Subclasses usually only override
    ``_make_channel``; trace replay overrides ``round`` wholesale."""

    #: registry key; aliases are extra names resolving to the same class
    name: str = ""
    aliases: tuple[str, ...] = ()
    #: environments that inherently materialise the population (trace
    #: replay) opt out of the virtual path and stay dense at any K
    supports_virtual: bool = True

    def __init__(self, fl: FLConfig, data_sizes=None):
        self.fl = fl
        self.virtual = is_virtual(fl) and self.supports_virtual
        self.participation = self._make_participation(fl)
        self.devices = (VirtualTierProfile(fl, data_sizes) if self.virtual
                        else self._make_devices(fl, data_sizes))
        self.channel = self._make_channel(fl)

    # component factories ------------------------------------------------
    def _make_participation(self, fl) -> Participation:
        return UniformParticipation(fl)

    def _make_devices(self, fl, data_sizes) -> DeviceProfile:
        return FixedTierProfile(fl, data_sizes)

    def _make_channel(self, fl) -> ChannelModel:
        raise NotImplementedError

    # the schedule contract ----------------------------------------------
    def round(self, t: int) -> RoundSchedule:
        """Round t's schedule — a pure function of (config, t)."""
        if self.virtual:
            b = self._vbatch(t, 1)
            return RoundSchedule(b["selected"][0], b["limited"][0],
                                 b["delayed"][0], b["delays"][0],
                                 b["data_sizes"][0])
        rng = round_rng(self.fl, t)
        sel = self.participation.select(t, rng)
        limited = self.devices.limited(sel)
        delayed, delays = self.channel.draw(t, sel, rng)
        return RoundSchedule(sel, limited, delayed, delays,
                             self.devices.sizes(sel))

    def batch(self, t0: int, n_rounds: int) -> dict[str, np.ndarray]:
        """Stacked (n_rounds, m) schedule arrays for the fused scan
        engine. Row i is BIT-IDENTICAL to ``round(t0 + i)`` — see the
        module docstring. Virtual populations evaluate the whole block
        in vectorised hashed draws (O(n*m), no per-round Python work);
        the dense path keeps the sequential per-round RandomState draws
        that define bit-identity at paper scale."""
        if self.virtual:
            return self._vbatch(t0, n_rounds)
        m = self.fl.clients_per_round
        out = {"selected": np.empty((n_rounds, m), np.int32),
               "limited": np.empty((n_rounds, m), bool),
               "delayed": np.empty((n_rounds, m), bool),
               "delays": np.empty((n_rounds, m), np.int32),
               "data_sizes": np.empty((n_rounds, m), np.float32)}
        for i in range(n_rounds):
            r = self.round(t0 + i)
            out["selected"][i] = r.selected
            out["limited"][i] = r.limited
            out["delayed"][i] = r.delayed
            out["delays"][i] = r.delays
            out["data_sizes"][i] = r.data_sizes
        return out

    def _vbatch(self, t0: int, n_rounds: int) -> dict[str, np.ndarray]:
        """The virtual-population block: selection, tier and channel are
        pure hashed functions of (client_id, seed, t), evaluated for the
        whole (n_rounds, m) block elementwise — nothing here scales with
        K. Both ``round`` and ``batch`` route through this when virtual,
        so the batch-row contract holds by construction."""
        sel = select_batch_hashed(self.fl, t0, n_rounds)
        delayed, delays = self.channel.draw_batch(t0, sel)
        return {"selected": sel,
                "limited": self.devices.limited(sel),
                "delayed": delayed,
                "delays": delays.astype(np.int32),
                "data_sizes": self.devices.sizes(sel)}


# ---------------------------------------------------------------------------
# registry (mirrors repro.core.strategies)
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, type[Environment]] = {}


def register(cls: type[Environment]) -> type[Environment]:
    """Class decorator: file-local registration under name + aliases."""
    assert cls.name, cls
    for key in (cls.name,) + tuple(cls.aliases):
        assert key not in _REGISTRY or _REGISTRY[key] is cls, key
        _REGISTRY[key] = cls
    return cls


def names() -> list[str]:
    """All registered environment names (aliases included), sorted."""
    return sorted(_REGISTRY)


def get(name: str) -> type[Environment]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown environment {name!r}; "
                       f"registered: {names()}") from None


def resolve(fl: FLConfig,
            data_sizes: np.ndarray | None = None) -> Environment:
    """Instantiate the environment for a config (``fl.env``)."""
    return get(fl.env)(fl, data_sizes=data_sizes)
