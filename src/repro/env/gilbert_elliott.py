"""Gilbert–Elliott bursty fading channel (two-state Markov, per client).

Each client's link sits in a Good or Bad state; per round it flips
Good->Bad with prob ``ge_p_gb`` and Bad->Good with prob ``ge_p_bg``.
Bad links delay uploads with high probability and draw LONG delays
(upper half of {1..max_delay}); good links rarely delay and draw short
ones — the bursty, temporally-correlated outages the i.i.d. Bernoulli
model cannot express (the realism gap named by arXiv:2307.10616).

Purity in t (the batch/round contract): the state trajectory over ALL K
clients is advanced with one ``side_rng(fl, s)`` stream per round s, so
the state at round t is a pure function of (seed, t) — independent of
which rounds were queried, in what order, or how they were batched. The
trajectory is memoized, so sequential sweeps stay O(1) per round.
"""
from __future__ import annotations

import numpy as np

from repro.env.base import ChannelModel, Environment, register, side_rng
from repro.env.virtual import TAG_DELAY, TAG_DELAY_LEN, TAG_GE, hash_u01


class GilbertElliottChannel(ChannelModel):
    def __init__(self, fl):
        super().__init__(fl)
        self._bad: list[np.ndarray] = []   # memoized state trajectory
        self._vmemo: dict[int, tuple[int, bool]] = {}  # virtual chains

    def _state(self, t: int) -> np.ndarray:
        """(K,) bool — Bad-state flags at round t (pure in (seed, t))."""
        fl = self.fl
        if not self._bad:
            # round 0: draw from the chain's stationary distribution
            p_bad = fl.ge_p_gb / max(fl.ge_p_gb + fl.ge_p_bg, 1e-9)
            self._bad.append(
                side_rng(fl, 0).rand(fl.num_clients) < p_bad)
        while len(self._bad) <= t:
            s = len(self._bad)
            u = side_rng(fl, s).rand(fl.num_clients)
            prev = self._bad[s - 1]
            self._bad.append(
                np.where(prev, u >= fl.ge_p_bg, u < fl.ge_p_gb))
        return self._bad[t]

    def draw(self, t, selected, rng):
        fl = self.fl
        m = len(selected)
        if fl.max_delay <= 0:
            return self._no_delays(m)
        bad = self._state(t)[selected]
        p = np.where(bad, fl.ge_p_delay_bad, fl.ge_p_delay_good)
        delayed = rng.rand(m) < p
        short = rng.randint(1, max(1, fl.max_delay // 3) + 1, size=m)
        long_ = rng.randint(max(1, (fl.max_delay + 1) // 2),
                            fl.max_delay + 1, size=m)
        delays = np.where(bad, long_, short).astype(np.int32)
        delays = np.where(delayed, delays, 1).astype(np.int32)
        return delayed, delays

    # virtual path: per-CLIENT hashed chains, no (K,) trajectory -------
    def _p_stationary(self) -> float:
        fl = self.fl
        return fl.ge_p_gb / max(fl.ge_p_gb + fl.ge_p_bg, 1e-9)

    def _bad_client(self, t: int, c: int) -> bool:
        """Client c's Bad flag at round t from its own hashed chain —
        a Markov state has no closed form, so the chain is advanced
        step-by-step but memoized per client: sequential sweeps cost
        O(delta_t) per selected client, not O(t) and never O(K)."""
        fl = self.fl
        s, st = self._vmemo.get(c, (-1, False))
        if s < 0 or s > t:
            st = bool(hash_u01(fl.seed, TAG_GE, 0, c) < self._p_stationary())
            s = 0
        while s < t:
            s += 1
            u = float(hash_u01(fl.seed, TAG_GE, s, c))
            st = (u >= fl.ge_p_bg) if st else (u < fl.ge_p_gb)
        self._vmemo[c] = (s, st)
        return st

    def draw_batch(self, t0, selected):
        fl = self.fl
        n, m = selected.shape
        if fl.max_delay <= 0:
            return np.zeros((n, m), bool), np.ones((n, m), np.int32)
        bad = np.array([[self._bad_client(t0 + i, int(c))
                         for c in selected[i]] for i in range(n)])
        t = np.arange(t0, t0 + n, dtype=np.int64)[:, None]
        p = np.where(bad, fl.ge_p_delay_bad, fl.ge_p_delay_good)
        delayed = hash_u01(fl.seed, TAG_DELAY, t, selected) < p
        u = hash_u01(fl.seed, TAG_DELAY_LEN, t, selected)
        short_hi = max(1, fl.max_delay // 3)
        long_lo = max(1, (fl.max_delay + 1) // 2)
        short = 1 + (u * short_hi).astype(np.int64)           # U{1..hi}
        long_ = long_lo + (u * (fl.max_delay + 1 - long_lo)).astype(
            np.int64)                                         # U{lo..max}
        delays = np.where(bad, long_, short)
        delays = np.where(delayed, delays, 1).astype(np.int32)
        return delayed, delays


@register
class GilbertElliottEnvironment(Environment):
    name = "gilbert_elliott"
    aliases = ("ge", "bursty")

    def _make_channel(self, fl):
        return GilbertElliottChannel(fl)
