"""Scenario registry: a name -> (environment, FLConfig knobs) binding.

A *scenario* is a reproducible experimental condition — the paper's
"moderate 30% delay" is one point; the registry makes the whole
algorithm x environment cross-product addressable by name from every
entry point (``--scenario`` on the launcher / examples, the
delay-tolerance benchmark, tests):

    fl = scenarios.apply(FLConfig(), "bursty")
    environment = env.resolve(fl)
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import FLConfig


@dataclass(frozen=True)
class Scenario:
    name: str
    env: str                       # environment registry key
    overrides: dict = field(default_factory=dict)   # FLConfig knobs
    description: str = ""

    def apply(self, fl: FLConfig) -> FLConfig:
        return fl.with_(env=self.env, **self.overrides)


_SCENARIOS: dict[str, Scenario] = {}


def register(sc: Scenario) -> Scenario:
    assert sc.name not in _SCENARIOS, sc.name
    _SCENARIOS[sc.name] = sc
    return sc


def names() -> list[str]:
    return sorted(_SCENARIOS)


def get(name: str) -> Scenario:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {names()}") from None


def apply(fl: FLConfig, name: str) -> FLConfig:
    """FLConfig with the named scenario's environment + knobs applied."""
    return get(name).apply(fl)


# ---------------------------------------------------------------------------
# built-in scenarios (paper §V points + beyond-paper channel models)
# ---------------------------------------------------------------------------
register(Scenario("clear", "bernoulli", {"p_delay": 0.0, "max_delay": 0},
                  "no transmission delay (paper's synchronous setting)"))
register(Scenario("moderate-30", "bernoulli",
                  {"p_delay": 0.3, "max_delay": 10},
                  "paper Fig. 3 moderate: 30% i.i.d. delay, max 10 rounds"))
register(Scenario("severe-70", "bernoulli",
                  {"p_delay": 0.7, "max_delay": 10},
                  "paper Fig. 3 severe: 70% i.i.d. delay, max 10 rounds"))
register(Scenario("bursty", "gilbert_elliott", {"max_delay": 10},
                  "Gilbert-Elliott fading: correlated outage bursts"))
register(Scenario("bursty-severe", "gilbert_elliott",
                  {"max_delay": 15, "ge_p_gb": 0.35, "ge_p_bg": 0.25},
                  "deep-fade regime: long Bad-state dwell, staleness 15"))
register(Scenario("bandwidth-limited", "bandwidth", {"max_delay": 10},
                  "log-normal uplink rate vs a round deadline"))
register(Scenario("mobility-trace", "trace",
                  {"max_delay": 10, "trace_path": ""},
                  "synthetic mobility replay: coverage-gated availability"))
