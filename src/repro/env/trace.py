"""Trace replay: drive rounds from a recorded schedule (.npz) instead of
a generative channel — testbed logs, deployment traces, or the synthetic
mobility trace below. The trace loops modulo its length, so any run
horizon replays it.

``.npz`` layout (all arrays (T, m)): ``selected`` int, ``limited`` bool,
``delayed`` bool, ``delays`` int (1 where on time); optional
``data_sizes`` float. ``save_trace`` writes any ``batch()`` output in
this layout, so every environment can be frozen into a replayable trace
(record once, sweep algorithms against the identical rounds).

With ``trace_path=""`` the environment synthesizes a MOBILITY trace:
each client moves through coverage on its own period/phase; it is
selectable only while in coverage, and uploads near the cell edge are
delayed proportionally to signal deficit — availability and staleness
become temporally correlated per client, which no i.i.d. draw models.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import FLConfig
from repro.env.base import (Environment, FixedTierProfile, RoundSchedule,
                            register, round_rng, side_rng)

TRACE_KEYS = ("selected", "limited", "delayed", "delays")


def save_trace(path: str, trace: dict[str, np.ndarray]) -> None:
    """Persist a stacked schedule (any ``Environment.batch`` output)."""
    missing = [k for k in TRACE_KEYS if k not in trace]
    assert not missing, f"trace missing arrays: {missing}"
    np.savez(path, **trace)


def synth_mobility_trace(fl: FLConfig,
                         rounds: int | None = None) -> dict[str, np.ndarray]:
    """Deterministic synthetic mobility trace (pure function of fl).

    Client i's signal is ``sin(2*pi*t / period_i + phase_i)`` plus
    per-round shadowing noise; the m strongest-signal clients
    participate (coverage-gated availability), and weak-signal uploads
    among them arrive late (delay grows with signal deficit).
    """
    T = rounds if rounds is not None else max(fl.rounds, 64)
    K, m = fl.num_clients, fl.clients_per_round
    assert m <= K, (m, K)
    geo = side_rng(fl, -7)  # static geometry stream (off the round axis)
    period = geo.uniform(20.0, 80.0, K)
    phase = geo.uniform(0.0, 2 * np.pi, K)
    profile = FixedTierProfile(fl)
    rows = {k: [] for k in TRACE_KEYS}
    for t in range(T):
        rng = round_rng(fl, t)
        sig = (np.sin(2 * np.pi * t / period + phase)
               + 0.15 * rng.randn(K))
        sel = np.argsort(-sig)[:m].astype(np.int32)
        s = sig[sel]
        if fl.max_delay > 0:
            delayed = s < 0.25
            frac = np.clip((0.25 - s) / 1.25, 0.0, 1.0)
            delays = np.clip(np.ceil(frac * fl.max_delay), 1,
                             fl.max_delay).astype(np.int32)
            delays = np.where(delayed, delays, 1).astype(np.int32)
        else:
            delayed = np.zeros(m, bool)
            delays = np.ones(m, np.int32)
        rows["selected"].append(sel)
        rows["limited"].append(profile.limited(sel))
        rows["delayed"].append(delayed)
        rows["delays"].append(delays)
    return {k: np.stack(v) for k, v in rows.items()}


@register
class TraceEnvironment(Environment):
    name = "trace"
    aliases = ("mobility",)
    # a trace IS a materialised population — (T, m) arrays on disk and
    # an O(K) synthesis loop — so it stays dense at any K
    supports_virtual = False

    def __init__(self, fl: FLConfig, data_sizes=None):
        super().__init__(fl, data_sizes)
        if fl.trace_path:
            with np.load(fl.trace_path) as npz:
                self._trace = {k: np.asarray(npz[k]) for k in TRACE_KEYS}
                self._trace_sizes = (np.asarray(npz["data_sizes"])
                                     if "data_sizes" in npz.files else None)
        else:
            self._trace = synth_mobility_trace(fl)
            self._trace_sizes = None
        sel = self._trace["selected"]
        assert sel.ndim == 2 and sel.shape[1] == fl.clients_per_round, \
            f"trace is (T, m)={sel.shape}, config m={fl.clients_per_round}"
        assert sel.max() < fl.num_clients, \
            f"trace selects client {sel.max()} >= num_clients={fl.num_clients}"
        for k in TRACE_KEYS[1:]:
            assert self._trace[k].shape == sel.shape, (k,
                                                       self._trace[k].shape)
        # delays beyond the config's staleness cap would wrap the async
        # ring buffer (Q = max_delay + 1 slots) into the wrong rounds
        delays, delayed = self._trace["delays"], self._trace["delayed"]
        assert delays.min() >= 1 and delays.max() <= max(fl.max_delay, 1), \
            (f"trace delays in [{delays.min()}, {delays.max()}] exceed "
             f"config max_delay={fl.max_delay}; replay with a config whose "
             f"max_delay covers the recording")
        assert (delays[~delayed.astype(bool)] == 1).all(), \
            "trace has delays != 1 on on-time uploads"

    def _make_channel(self, fl):
        return None  # the trace IS the channel

    def round(self, t: int) -> RoundSchedule:
        r = t % len(self._trace["selected"])
        sel = self._trace["selected"][r].astype(np.int32)
        if self.devices.has_sizes:
            sizes = self.devices.sizes(sel)
        elif self._trace_sizes is not None:
            sizes = self._trace_sizes[r].astype(np.float32)
        else:
            sizes = np.ones(len(sel), np.float32)
        return RoundSchedule(sel,
                             self._trace["limited"][r].astype(bool),
                             self._trace["delayed"][r].astype(bool),
                             self._trace["delays"][r].astype(np.int32),
                             sizes)
