"""The seed's i.i.d. Bernoulli-delay environment (paper §V settings).

Uploads are independently delayed with probability ``p_delay`` each
round; the delay is uniform on {1..max_delay}. Draw order is exactly the
seed ``HeterogeneitySchedule`` algorithm — ``env.get("bernoulli")`` is
bit-identical to it (enforced by tests/test_env.py), and
``HeterogeneitySchedule`` itself is now a thin wrapper over this class.
"""
from __future__ import annotations

import numpy as np

from repro.env.base import ChannelModel, Environment, register
from repro.env.virtual import TAG_DELAY, TAG_DELAY_LEN, hash_u01


class BernoulliChannel(ChannelModel):
    """Delayed ~ Bernoulli(p_delay), delay ~ U{1..max_delay}, i.i.d."""

    def draw(self, t, selected, rng):
        fl = self.fl
        m = len(selected)
        if fl.max_delay > 0 and fl.p_delay > 0:
            delayed = rng.rand(m) < fl.p_delay
            delays = rng.randint(1, fl.max_delay + 1,
                                 size=m).astype(np.int32)
        else:
            delayed = np.zeros(m, bool)
            delays = np.ones(m, np.int32)
        delays = np.where(delayed, delays, 1).astype(np.int32)
        return delayed, delays

    def draw_batch(self, t0, selected):
        """Virtual path: the whole (n_rounds, m) block in two hashed
        draws keyed on (t, client) — i.i.d. across both, like the dense
        channel, with no per-round Python work."""
        fl = self.fl
        n, m = selected.shape
        if fl.max_delay <= 0 or fl.p_delay <= 0:
            return np.zeros((n, m), bool), np.ones((n, m), np.int32)
        t = np.arange(t0, t0 + n, dtype=np.int64)[:, None]
        delayed = hash_u01(fl.seed, TAG_DELAY, t, selected) < fl.p_delay
        delays = 1 + (hash_u01(fl.seed, TAG_DELAY_LEN, t, selected)
                      * fl.max_delay).astype(np.int64)  # U{1..max_delay}
        delays = np.where(delayed, delays, 1).astype(np.int32)
        return delayed, delays


@register
class BernoulliEnvironment(Environment):
    name = "bernoulli"
    aliases = ("iid_delay",)

    def _make_channel(self, fl):
        return BernoulliChannel(fl)
