"""SNR/bandwidth channel: upload latency against a round deadline.

Per round each selected client draws an uplink rate from a log-normal
distribution (``bw_mean_mbps`` median, ``bw_sigma`` log-std — the usual
shadow-fading model); uploading the ``bw_upload_mbits`` model update
then takes ``latency = bits / rate`` seconds. A round closes after
``bw_deadline_s`` seconds, so an upload that needs r deadlines arrives
with ``r - 1`` rounds of staleness:

    delayed = latency > deadline
    delay   = clip(ceil(latency / deadline) - 1, 1, max_delay)

This maps a physical channel (rate in Mbps, deadline in seconds) onto
the paper's abstract delay-rounds without touching the aggregation rule.
"""
from __future__ import annotations

import numpy as np

from repro.env.base import ChannelModel, Environment, register
from repro.env.virtual import TAG_DELAY, TAG_DELAY_LEN, hash_u01


class BandwidthChannel(ChannelModel):
    def draw(self, t, selected, rng):
        fl = self.fl
        m = len(selected)
        if fl.max_delay <= 0:
            return self._no_delays(m)
        rate = fl.bw_mean_mbps * np.exp(fl.bw_sigma * rng.randn(m))
        return self._delays_from_rate(rate)

    def _delays_from_rate(self, rate):
        fl = self.fl
        # the ACTUAL bits on the wire: the comm plane's compression
        # ratio scales the upload, so delay tolerance (paper Fig. 3)
        # becomes a function of the compression level. wire_fraction is
        # exactly 1.0 for comm_plane="none" — the dense path's delay
        # draws are untouched (bit-identity contract).
        from repro.comm import wire_fraction
        upload = fl.bw_upload_mbits * wire_fraction(fl)
        latency = upload / np.maximum(rate, 1e-9)
        deadlines = np.ceil(latency / fl.bw_deadline_s).astype(np.int64)
        delayed = deadlines > 1
        delays = np.clip(deadlines - 1, 1, fl.max_delay).astype(np.int32)
        delays = np.where(delayed, delays, 1).astype(np.int32)
        return delayed, delays

    def draw_batch(self, t0, selected):
        """Virtual path: shadow-fading normals for the whole block via
        Box-Muller over two hashed uniforms keyed on (t, client)."""
        fl = self.fl
        n, m = selected.shape
        if fl.max_delay <= 0:
            return np.zeros((n, m), bool), np.ones((n, m), np.int32)
        t = np.arange(t0, t0 + n, dtype=np.int64)[:, None]
        u1 = hash_u01(fl.seed, TAG_DELAY, t, selected)
        u2 = hash_u01(fl.seed, TAG_DELAY_LEN, t, selected)
        z = np.sqrt(-2.0 * np.log(np.maximum(u1, 1e-12))) \
            * np.cos(2.0 * np.pi * u2)
        return self._delays_from_rate(fl.bw_mean_mbps
                                      * np.exp(fl.bw_sigma * z))


@register
class BandwidthEnvironment(Environment):
    name = "bandwidth"
    aliases = ("snr",)

    def _make_channel(self, fl):
        return BandwidthChannel(fl)
