"""Virtual populations: a million-client federation without K-length
arrays.

Everything in the dense environment path materialises the population
somewhere — the O(K) permutation inside ``rng.choice(K, m,
replace=False)``, the ``FixedTierProfile`` membership set drawn over all
K clients, the Gilbert–Elliott (K,) state trajectory, the per-client
``data_sizes`` vector. At paper scale (K = 20..50) that is free; at the
population sizes where the paper's asynchronous/staleness machinery is
actually stressed (K = 10^5..10^6, sparse participation) it is the
per-round bottleneck and the memory floor.

This module is the K-free replacement: a ``VirtualPopulation`` treats
the population as a PURE FUNCTION of ``(client_id, seed, t)`` —

  * participation: a vectorised counter-hash rejection sampler draws the
    (n_rounds, m) cohort index matrix directly, O(n*m) total with no
    permutation and no RNG object per client;
  * limited-ness / tier: a per-client hashed Bernoulli(p_limited) coin,
    evaluated only for selected clients;
  * data size: arithmetic (every virtual client owns a fixed-size shard
    of the base store — see ``data.pipeline.VirtualClientShards``) or a
    caller-supplied per-client function.

The hash is splitmix64 over (seed, tag, counters...) — deterministic,
stateless, vectorised, and independent per (tag, t, client) stream, so
the ``Environment.batch(t0, n)`` row i == ``round(t0 + i)`` contract
holds by construction however rounds are chunked or reordered.

THE GUARD: virtual draws are necessarily a *different* stream from the
dense RandomState algorithms, so they only engage beyond paper scale.
``is_virtual(fl)`` is True when ``fl.population == "virtual"`` or when
``fl.population == "auto"`` (the default) and K > ``VIRTUAL_K_MIN``;
below that every draw stays bit-identical to the seed's dense path
(enforced by tests/test_federation_scale.py). Independently,
``floyd_sample`` replaces the O(K) permutation inside dense
``UniformParticipation.select`` once K > ``DENSE_SELECT_MAX`` — an O(m)
classic Floyd draw from the same per-round RandomState stream.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import FLConfig

#: dense UniformParticipation keeps the seed's ``rng.choice`` draw (and
#: therefore bit-identity with the paper-scale reference) up to this K;
#: above it the O(m) Floyd sampler takes over
DENSE_SELECT_MAX = 4096

#: ``population="auto"`` switches the whole environment to the virtual
#: (hashed) population above this K
VIRTUAL_K_MIN = 65536

# stream tags: one independent hashed stream per schedule component
TAG_SELECT = 0x53454C  # participation rejection sampler
TAG_LIMITED = 0x4C494D  # per-client limited-ness coin
TAG_DELAY = 0x44454C  # bernoulli channel: delayed coin
TAG_DELAY_LEN = 0x444C4E  # bernoulli channel: delay length
TAG_GE = 0x47455354  # gilbert-elliott per-client state chain


def is_virtual(fl: FLConfig) -> bool:
    """Does this config run the hashed (K-free) population machinery?"""
    mode = getattr(fl, "population", "auto")
    if mode == "dense":
        return False
    if mode == "virtual":
        return True
    if mode != "auto":
        raise ValueError(f"unknown population mode {mode!r}; "
                         "expected 'auto' | 'dense' | 'virtual'")
    return fl.num_clients > VIRTUAL_K_MIN


# ---------------------------------------------------------------------------
# counter-based hashing (splitmix64): stateless per-(tag, counters) draws
# ---------------------------------------------------------------------------
_U64 = np.uint64
_GOLDEN = _U64(0x9E3779B97F4A7C15)
_MIX1 = _U64(0xBF58476D1CE4E5B9)
_MIX2 = _U64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):
        z = x + _GOLDEN
        z = (z ^ (z >> _U64(30))) * _MIX1
        z = (z ^ (z >> _U64(27))) * _MIX2
        return z ^ (z >> _U64(31))


def hash_bits(seed: int, tag: int, *counters) -> np.ndarray:
    """Vectorised 64-bit hash of (seed, tag, counters...); the counters
    broadcast against each other like any numpy operands."""
    h = _splitmix64(np.asarray(int(seed) & 0xFFFFFFFFFFFFFFFF, _U64)
                    ^ _U64(int(tag) & 0xFFFFFFFFFFFFFFFF))
    for c in counters:
        c = np.asarray(c)
        with np.errstate(over="ignore"):
            h = _splitmix64(h ^ c.astype(_U64))
    return h


def hash_u01(seed: int, tag: int, *counters) -> np.ndarray:
    """Uniform [0, 1) float64 draws from the hashed stream (53-bit)."""
    return (hash_bits(seed, tag, *counters) >> _U64(11)) * (2.0 ** -53)


# ---------------------------------------------------------------------------
# O(m) without-replacement sampling
# ---------------------------------------------------------------------------
def floyd_sample(rng: np.random.RandomState, K: int, m: int) -> np.ndarray:
    """Floyd's classic O(m) uniform without-replacement draw of m of K,
    consuming m ``randint`` draws from ``rng`` (no O(K) permutation).
    Returned order is the insertion order (deterministic given rng)."""
    assert 0 < m <= K, (m, K)
    chosen: dict[int, None] = {}        # insertion-ordered set
    for j in range(K - m, K):
        t = int(rng.randint(0, j + 1))
        chosen[j if t in chosen else t] = None
    return np.fromiter(chosen, np.int32, count=m)


def _row_dup_mask(sel: np.ndarray) -> np.ndarray:
    """(n, m) bool: True where an entry repeats an EARLIER entry of its
    row (the earliest occurrence of each value is kept)."""
    order = np.argsort(sel, axis=1, kind="stable")
    s = np.take_along_axis(sel, order, axis=1)
    eq = np.zeros_like(s, bool)
    eq[:, 1:] = s[:, 1:] == s[:, :-1]
    out = np.zeros_like(eq)
    np.put_along_axis(out, order, eq, axis=1)
    return out


def select_batch_hashed(fl: FLConfig, t0: int, n: int) -> np.ndarray:
    """(n, m) int32 cohort matrix for rounds t0..t0+n-1, drawn without
    replacement per round from the hashed stream — O(n*m) expected,
    vectorised over the whole chunk, pure in t per row.

    Candidates are keyed on (t, slot, attempt); within-round duplicates
    are re-hashed with a bumped attempt counter (collision probability
    ~ m^2 / 2K per round, so a couple of passes suffice at virtual
    scale). The pathological tail falls back to the per-round Floyd
    draw, which is pure in t too.
    """
    K, m = fl.num_clients, fl.clients_per_round
    assert m <= K, (m, K)
    t = np.arange(t0, t0 + n, dtype=np.int64)[:, None]
    slot = np.arange(m, dtype=np.int64)[None, :]
    sel = np.minimum((hash_u01(fl.seed, TAG_SELECT, t, slot) * K), K - 1
                     ).astype(np.int64)
    for attempt in range(1, 32):
        dup = _row_dup_mask(sel)
        if not dup.any():
            break
        fresh = np.minimum(
            hash_u01(fl.seed, TAG_SELECT + attempt, t, slot) * K, K - 1
        ).astype(np.int64)
        sel = np.where(dup, fresh, sel)
    else:  # unreachable for m << K; stay pure in t regardless
        from repro.env.base import round_rng
        for i in np.flatnonzero(_row_dup_mask(sel).any(axis=1)):
            sel[i] = floyd_sample(round_rng(fl, int(t0 + i)), K, m)
    return sel.astype(np.int32)


# ---------------------------------------------------------------------------
# the population as a pure function of (client_id, seed)
# ---------------------------------------------------------------------------
class VirtualPopulation:
    """K clients that exist only as hash/arithmetic functions.

    ``sizes_fn`` (optional) maps a client-id array to per-client data
    sizes (|D_i| aggregation weights) — ``data.pipeline
    .VirtualClientShards.client_sizes`` is the arithmetic counterpart on
    the staging side; default is uniform weight 1. All methods accept
    client-id arrays of ANY shape and evaluate elementwise, so the whole
    (n_rounds, m) schedule block hashes in one vectorised call.
    """

    def __init__(self, fl: FLConfig, sizes_fn=None):
        self.fl = fl
        self.sizes_fn = sizes_fn

    def select_batch(self, t0: int, n: int) -> np.ndarray:
        return select_batch_hashed(self.fl, t0, n)

    def limited(self, selected: np.ndarray) -> np.ndarray:
        """Hashed Bernoulli(p_limited) coin per client — the virtual
        counterpart of ``FixedTierProfile``'s fixed membership set."""
        selected = np.asarray(selected)
        return (hash_u01(self.fl.seed, TAG_LIMITED, selected)
                < self.fl.p_limited)

    def tier(self, selected: np.ndarray) -> np.ndarray:
        return np.where(self.limited(selected), 0, 1).astype(np.int32)

    def sizes(self, selected: np.ndarray) -> np.ndarray:
        selected = np.asarray(selected)
        if self.sizes_fn is None:
            return np.ones(selected.shape, np.float32)
        return np.asarray(self.sizes_fn(selected), np.float32)
