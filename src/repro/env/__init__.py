"""Pluggable heterogeneous-environment subsystem — the one home for
"what does the world do to the clients". Importing this package
registers the built-in environments:

    bernoulli (alias iid_delay) | gilbert_elliott (ge, bursty)
    | bandwidth (snr) | trace (mobility)

Use ``resolve(fl)`` to get the environment for a config (``fl.env``),
``get(name)`` / ``names()`` to address the registry directly, and
``scenarios`` for named environment + FLConfig-knob bindings.
"""
from repro.env import scenarios
from repro.env.base import (ChannelModel, DeviceProfile, Environment,
                            FixedTierProfile, Participation, RoundSchedule,
                            UniformParticipation, get, names, register,
                            resolve, round_rng, side_rng)
from repro.env.bandwidth import BandwidthEnvironment
from repro.env.bernoulli import BernoulliEnvironment
from repro.env.gilbert_elliott import GilbertElliottEnvironment
from repro.env.trace import (TraceEnvironment, save_trace,
                             synth_mobility_trace)

__all__ = ["Environment", "ChannelModel", "DeviceProfile", "Participation",
           "RoundSchedule", "FixedTierProfile", "UniformParticipation",
           "register", "resolve", "get", "names", "round_rng", "side_rng",
           "scenarios", "BernoulliEnvironment", "GilbertElliottEnvironment",
           "BandwidthEnvironment", "TraceEnvironment", "save_trace",
           "synth_mobility_trace"]
