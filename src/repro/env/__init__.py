"""Pluggable heterogeneous-environment subsystem — the one home for
"what does the world do to the clients". Importing this package
registers the built-in environments:

    bernoulli (alias iid_delay) | gilbert_elliott (ge, bursty)
    | bandwidth (snr) | trace (mobility)

Use ``resolve(fl)`` to get the environment for a config (``fl.env``),
``get(name)`` / ``names()`` to address the registry directly, and
``scenarios`` for named environment + FLConfig-knob bindings.
"""
from repro.env import scenarios
from repro.env.base import (ChannelModel, DeviceProfile, Environment,
                            FixedTierProfile, Participation, RoundSchedule,
                            UniformParticipation, VirtualTierProfile, get,
                            names, register, resolve, round_rng, side_rng)
from repro.env.bandwidth import BandwidthEnvironment
from repro.env.bernoulli import BernoulliEnvironment
from repro.env.gilbert_elliott import GilbertElliottEnvironment
from repro.env.trace import (TraceEnvironment, save_trace,
                             synth_mobility_trace)
from repro.env.virtual import (DENSE_SELECT_MAX, VIRTUAL_K_MIN,
                               VirtualPopulation, floyd_sample, hash_u01,
                               is_virtual, select_batch_hashed)

__all__ = ["Environment", "ChannelModel", "DeviceProfile", "Participation",
           "RoundSchedule", "FixedTierProfile", "UniformParticipation",
           "VirtualTierProfile", "VirtualPopulation", "is_virtual",
           "floyd_sample", "select_batch_hashed", "hash_u01",
           "DENSE_SELECT_MAX", "VIRTUAL_K_MIN",
           "register", "resolve", "get", "names", "round_rng", "side_rng",
           "scenarios", "BernoulliEnvironment", "GilbertElliottEnvironment",
           "BandwidthEnvironment", "TraceEnvironment", "save_trace",
           "synth_mobility_trace"]
