"""Checkpointing: flat-key npz save/restore for param/opt/queue pytrees."""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        arr = np.asarray(tree)
        if arr.dtype.kind == "V":      # bfloat16 etc: store lossless as f32
            arr = np.asarray(tree, dtype=np.float32)
        out[prefix[:-1]] = arr
    return out


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, like):
    """Restore into the structure of ``like`` (dtypes preserved from disk)."""
    with np.load(path) as zf:
        flat = dict(zf)

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        leaf = flat[prefix[:-1]]
        return jax.numpy.asarray(leaf).astype(tree.dtype) \
            if hasattr(tree, "dtype") else jax.numpy.asarray(leaf)

    return rebuild(like)
