"""Checkpointing: flat-key npz save/restore for param/opt/queue pytrees
and the engine's full round state ``{params, t, aux}``.

Writes are atomic (tmp file + rename), so a checkpoint taken mid-run
can never be half-written; ``save_state``/``restore_state`` round-trip
the WHOLE round carry — global params, the round index ``t`` and the
strategy aux state (async-AMA ring buffer, fedopt Adam moments, ...) —
bit-identically, which is what makes ``--resume`` continuation exact
(tests/test_engine.py proves the save→restore→continue identity).
"""
from __future__ import annotations

import os
import uuid

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            if "/" in str(k):
                # '/' is the flat-key separator: {"a/b": x} and
                # {"a": {"b": y}} would land on the SAME flat key and
                # one leaf would silently overwrite the other
                raise ValueError(
                    f"checkpoint dict key {k!r} contains '/' — flat npz "
                    "keys are '/'-joined paths, so such keys can collide "
                    "with another leaf; rename the key")
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        arr = np.asarray(tree)
        if arr.dtype.kind == "V":      # bfloat16 etc: store lossless as f32
            arr = np.asarray(tree, dtype=np.float32)
        out[prefix[:-1]] = arr
    return out


def _with_npz(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def _tmp_path(final: str) -> str:
    """Per-writer-unique tmp name (.npz suffix: savez won't rename it).
    A fixed name let two concurrent checkpointers of the same path
    clobber each other's half-written tmp file before the rename."""
    return f"{final}.{os.getpid()}.{uuid.uuid4().hex[:8]}.tmp.npz"


def save(path: str, tree) -> None:
    final = _with_npz(path)
    os.makedirs(os.path.dirname(os.path.abspath(final)), exist_ok=True)
    flat = _flatten(tree)              # validate keys before touching disk
    tmp = _tmp_path(final)
    try:
        np.savez(tmp, **flat)
        os.replace(tmp, final)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def restore(path: str, like, prefix: str = ""):
    """Restore into the structure of ``like`` (dtypes preserved from
    disk). Members are read lazily — only the flat keys ``like`` asks
    for are decompressed, so restoring a subtree (``prefix``, e.g.
    ``"params/"`` out of a round-state file) never materializes the
    rest (optimizer moments, async ring buffers)."""
    with np.load(_with_npz(path)) as zf:

        def rebuild(tree, pfx):
            if isinstance(tree, dict):
                return {k: rebuild(v, f"{pfx}{k}/") for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                vals = [rebuild(v, f"{pfx}{i}/") for i, v in enumerate(tree)]
                return type(tree)(vals)
            leaf = zf[pfx[:-1]]
            return jax.numpy.asarray(leaf).astype(tree.dtype) \
                if hasattr(tree, "dtype") else jax.numpy.asarray(leaf)

        return rebuild(like, prefix)


def restore_params(path: str, like_params):
    """Restore a PARAMS pytree from either a bare params checkpoint or a
    full round-state file written by ``save_state`` (keys
    ``params/...``-prefixed plus ``t``/``aux``). The serving path used
    to call plain ``restore`` and KeyError on round-state files the
    trainer's ``--checkpoint`` writes; this detects the round-state
    layout and slices out the params subtree."""
    with np.load(_with_npz(path)) as zf:
        keys = set(zf.files)
    if "t" in keys and any(k.startswith("params/") for k in keys):
        return restore(path, like_params, prefix="params/")
    return restore(path, like_params)


def save_state(path: str, state: dict) -> None:
    """Checkpoint a full round state ``{params, t, aux}`` (any strategy:
    the aux pytree carries ring buffers / moments / {} unchanged)."""
    missing = {"params", "t"} - set(state)
    if missing:
        raise ValueError(f"round state missing keys: {sorted(missing)}")
    save(path, state)


def restore_state(path: str, like_state: dict) -> dict:
    """Restore a full round state into the structure of ``like_state``
    (use ``core.round.init_state`` to build the template)."""
    with np.load(_with_npz(path)) as zf:
        keys = set(zf.files)
    if "t" not in keys or not any(k.startswith("params/") for k in keys):
        raise ValueError(
            f"{path} is not a full round-state checkpoint "
            "({params, t, aux} — e.g. a params-only file from an older "
            "save); re-save with save_state / --checkpoint")
    return restore(path, like_state)
