"""Checkpointing: flat-key npz save/restore for param/opt/queue pytrees
and the engine's full round state ``{params, t, aux}``.

Writes are atomic (tmp file + rename), so a checkpoint taken mid-run
can never be half-written; ``save_state``/``restore_state`` round-trip
the WHOLE round carry — global params, the round index ``t`` and the
strategy aux state (async-AMA ring buffer, fedopt Adam moments, ...) —
bit-identically, which is what makes ``--resume`` continuation exact
(tests/test_engine.py proves the save→restore→continue identity).
"""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        arr = np.asarray(tree)
        if arr.dtype.kind == "V":      # bfloat16 etc: store lossless as f32
            arr = np.asarray(tree, dtype=np.float32)
        out[prefix[:-1]] = arr
    return out


def _with_npz(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def save(path: str, tree) -> None:
    final = _with_npz(path)
    os.makedirs(os.path.dirname(os.path.abspath(final)), exist_ok=True)
    tmp = final + ".tmp.npz"           # .npz suffix: savez won't rename it
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, final)


def restore(path: str, like):
    """Restore into the structure of ``like`` (dtypes preserved from disk)."""
    with np.load(_with_npz(path)) as zf:
        flat = dict(zf)

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        leaf = flat[prefix[:-1]]
        return jax.numpy.asarray(leaf).astype(tree.dtype) \
            if hasattr(tree, "dtype") else jax.numpy.asarray(leaf)

    return rebuild(like)


def save_state(path: str, state: dict) -> None:
    """Checkpoint a full round state ``{params, t, aux}`` (any strategy:
    the aux pytree carries ring buffers / moments / {} unchanged)."""
    missing = {"params", "t"} - set(state)
    if missing:
        raise ValueError(f"round state missing keys: {sorted(missing)}")
    save(path, state)


def restore_state(path: str, like_state: dict) -> dict:
    """Restore a full round state into the structure of ``like_state``
    (use ``core.round.init_state`` to build the template)."""
    with np.load(_with_npz(path)) as zf:
        keys = set(zf.files)
    if "t" not in keys or not any(k.startswith("params/") for k in keys):
        raise ValueError(
            f"{path} is not a full round-state checkpoint "
            "({params, t, aux} — e.g. a params-only file from an older "
            "save); re-save with save_state / --checkpoint")
    return restore(path, like_state)
