"""Federation-scale sweep: rounds/sec vs population size K x cohort C.

The point of the virtual-population machinery (``repro.env.virtual``,
``data.pipeline.VirtualClientShards``) is that per-round scheduling +
staging cost grows with the COHORT size C, not the population size K —
a 10^6-client federation rounds as fast as a 10^3-client one. This sweep
measures exactly that claim end-to-end through the chunked-scan engine
(``FederatedSimulation``): K in {10^3, 10^4, 10^5, 10^6} x C in
{5, 32, 128}, with ``population="auto"`` choosing the realisation the
engine would really use at each K (dense below VIRTUAL_K_MIN, hashed
virtual above). Reported per cell:

  * ``rounds_per_sec``      — end-to-end engine throughput;
  * ``sched_stage_ms``      — host-side schedule + staging cost per
                              round (the O(K) -> O(C) claim in isolation);
  * ``sublinearity``        — per C, rounds/sec at K=10^6 over K=10^3
                              (~1.0 when scheduling is population-free).

Emits ``BENCH_federation_scale.json`` at the repo root; the ``--smoke``
configuration (K in {10^3, 10^6}, C=5) is re-run by
``scripts/check_bench.py`` as a CI regression gate on ``scale_ratio``.
"""
from __future__ import annotations

import json
import os
import time

from repro.obs.provenance import provenance
from repro.obs.timing import sync_time

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.pipeline import VirtualClientShards
from repro.data.synth import make_image_classification
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                   "BENCH_federation_scale.json")

#: every client owns a fixed-size shard view of the shared base store,
#: so steps/round (and therefore the compiled program) is identical
#: across the whole K sweep — only scheduling/staging cost can differ
SHARD_SIZE = 32

POPULATIONS = (1_000, 10_000, 100_000, 1_000_000)
COHORTS = (5, 32, 128)


def _fl(K: int, C: int) -> FLConfig:
    return FLConfig(num_clients=K, clients_per_round=C,
                    local_epochs=1, local_batch_size=16, lr=0.1,
                    algorithm="ama_fes", env="bernoulli",
                    p_delay=0.3, max_delay=6, population="auto", seed=0)


def _cell(model, train, test, K: int, C: int, *, rounds: int,
          reps: int) -> dict:
    fl = _fl(K, C)
    clients = VirtualClientShards(train, K, shard_size=SHARD_SIZE,
                                  seed=fl.seed)
    sim = FederatedSimulation(model, fl, clients, test)
    # host-side cost in isolation: schedule draw + chunk staging
    sim._stage(0, rounds)                               # warm (GE memo etc.)
    # host-side numpy: nothing to sync, but perf_counter is monotonic
    t0 = time.perf_counter()
    for _ in range(max(reps, 2)):
        sim._stage(0, rounds)
    sched_stage_ms = ((time.perf_counter() - t0)
                      / max(reps, 2) / rounds * 1e3)
    # end-to-end engine throughput (compile + warm first); sync_time
    # closes each span with block_until_ready (obs.timing)
    sim.run(rounds=rounds, eval_every=rounds)
    best = float("inf")
    for _ in range(reps):
        dt, _ = sync_time(sim.run, rounds=rounds, eval_every=rounds)
        best = min(best, dt)
    return {"population": "virtual" if sim.env.virtual else "dense",
            "rounds_per_sec": round(rounds / best, 3),
            "per_round_ms": round(best / rounds * 1e3, 2),
            "sched_stage_ms": round(sched_stage_ms, 3)}


SMOKE = dict(rounds=4, reps=2, n_train=1024, cohort=5,
             populations=(1_000, 1_000_000))


def _smoke_rec(*, rounds, reps, n_train, cohort, populations) -> dict:
    model = build_model(ARCHS["paper-cnn"])
    train, test = make_image_classification(n_train=n_train, n_test=256,
                                            seed=0)
    cells = {K: _cell(model, train, test, K, cohort, rounds=rounds,
                      reps=reps) for K in populations}
    lo, hi = populations[0], populations[-1]
    ratio = round(cells[hi]["rounds_per_sec"]
                  / max(cells[lo]["rounds_per_sec"], 1e-9), 3)
    return {"cohort": cohort,
            "cells": {str(K): c for K, c in cells.items()},
            "scale_ratio": ratio, "gate": round(ratio * 0.8, 3)}


def run(quick: bool = True, smoke: bool = False) -> dict:
    if smoke:
        rec = _smoke_rec(**SMOKE)
        rec["provenance"] = provenance()
        lo, hi = (str(K) for K in SMOKE["populations"])
        print(f"federation_scale.rps_k1e3,"
              f"{rec['cells'][lo]['rounds_per_sec']},")
        print(f"federation_scale.rps_k1e6,"
              f"{rec['cells'][hi]['rounds_per_sec']},")
        print(f"federation_scale.scale_ratio,{rec['scale_ratio']},"
              f"rounds/sec at K=1e6 over K=1e3 (smoke; ~1.0 = "
              f"population-free scheduling)")
        return rec

    rounds, reps = (4 if quick else 8), (2 if quick else 3)
    model = build_model(ARCHS["paper-cnn"])
    train, test = make_image_classification(n_train=2048, n_test=256,
                                            seed=0)
    grid: dict[str, dict] = {}
    for C in COHORTS:
        for K in POPULATIONS:
            cell = _cell(model, train, test, K, C, rounds=rounds,
                         reps=reps)
            grid[f"K{K}_C{C}"] = cell
            print(f"federation_scale.K{K}_C{C},"
                  f"{cell['rounds_per_sec']},rounds/sec "
                  f"({cell['population']}, sched+stage "
                  f"{cell['sched_stage_ms']} ms/round)")
    sub = {f"C{C}": round(grid[f"K{POPULATIONS[-1]}_C{C}"]["rounds_per_sec"]
                          / max(grid[f"K{POPULATIONS[0]}_C{C}"]
                                ["rounds_per_sec"], 1e-9), 3)
           for C in COHORTS}
    for c, r in sub.items():
        print(f"federation_scale.sublinearity_{c},{r},rps(K=1e6)/rps(K=1e3)")
    rec = {"bench": "federation_scale", "arch": "paper-cnn",
           "algorithm": "ama_fes", "env": "bernoulli",
           "shard_size": SHARD_SIZE, "rounds": rounds,
           "populations": list(POPULATIONS), "cohorts": list(COHORTS),
           "grid": grid, "sublinearity": sub,
           "provenance": provenance()}
    # CI regression-gate baseline: the exact configuration the smoke
    # gate re-runs (scripts/check_bench.py), variance-discounted
    s = _smoke_rec(**SMOKE)
    rec["smoke"] = {"scale_ratio": s["scale_ratio"], "gate": s["gate"]}
    print(f"federation_scale.smoke_scale_ratio,{s['scale_ratio']},"
          f"gate baseline {s['gate']}")
    with open(OUT, "w") as f:
        json.dump(rec, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(OUT)}")
    return rec


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv, smoke="--smoke" in sys.argv)
