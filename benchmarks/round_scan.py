"""Round-engine throughput: fused N-round lax.scan vs per-round-jit loop.

Measures the dispatch-overhead win of compiling the whole run into ONE
XLA program (core.round.make_train_loop) against the seed's architecture
of one jitted call per round: compile time once, then steady-state
per-round wall time for both engines on the same reduced transformer
and identical schedules. The python loop pays a host round-trip + jit
dispatch every round; the scan pays neither.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.timing import sync_time

from repro import env as env_mod
from repro.configs.base import FLConfig, reduced
from repro.configs.registry import ARCHS
from repro.core.round import (as_scan_scheds, init_state, make_round_step,
                              make_train_loop)
from repro.models.api import build_model


def _setup(rounds: int, C: int = 2, steps: int = 2, b: int = 2, S: int = 32):
    cfg = reduced(ARCHS["minitron-8b"])
    model = build_model(cfg)
    fl = FLConfig(algorithm="ama_fes", cohorts=C, local_steps=steps, lr=0.05)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (C, steps, b, S)), jnp.int32)}
    environment = env_mod.resolve(
        fl.with_(num_clients=C, clients_per_round=C))
    scheds = as_scan_scheds(environment.batch(0, rounds))
    return model, fl, batch, scheds


def run(quick: bool = True, smoke: bool = False) -> dict:
    rounds = 4 if smoke else (8 if quick else 32)
    model, fl, batch, scheds = _setup(rounds)

    # --- baseline: one jitted call per round (seed architecture)
    # timing via obs.timing.sync_time: perf_counter spans closed by
    # block_until_ready on the outputs (async-dispatch-safe)
    step = jax.jit(make_round_step(model, fl))
    state = init_state(model, fl, jax.random.PRNGKey(0))
    sched0 = jax.tree.map(lambda x: x[0], scheds)
    loop_compile_s, (state, m) = sync_time(step, state, batch, sched0)

    def _loop_rounds(state):
        for r in range(1, rounds):
            state, m = step(state, batch,
                            jax.tree.map(lambda x, r=r: x[r], scheds))
        return state, m

    loop_s, _ = sync_time(_loop_rounds, state)
    loop_per_round_ms = loop_s / max(rounds - 1, 1) * 1e3

    # --- fused scan: the whole run is one XLA program
    loop_fn = make_train_loop(model, fl, donate=False)
    state0 = init_state(model, fl, jax.random.PRNGKey(0))
    scan_first_s, _ = sync_time(loop_fn, state0, batch, scheds)
    scan_s, _ = sync_time(loop_fn, state0, batch, scheds)
    scan_per_round_ms = scan_s / rounds * 1e3
    scan_compile_s = scan_first_s - scan_per_round_ms * rounds / 1e3

    rec = {"rounds": rounds,
           "python_loop_per_round_ms": round(loop_per_round_ms, 2),
           "scan_per_round_ms": round(scan_per_round_ms, 2),
           "dispatch_overhead_ms": round(
               loop_per_round_ms - scan_per_round_ms, 2),
           "speedup": round(loop_per_round_ms
                            / max(scan_per_round_ms, 1e-9), 2),
           "python_loop_compile_s": round(loop_compile_s, 2),
           "scan_compile_s": round(max(scan_compile_s, 0.0), 2)}
    print(f"round_scan.python_loop_per_round_ms,"
          f"{rec['python_loop_per_round_ms']},")
    print(f"round_scan.scan_per_round_ms,{rec['scan_per_round_ms']},")
    print(f"round_scan.speedup,{rec['speedup']},"
          f"x over per-round jit ({rounds} rounds)")
    print(f"round_scan.compile_s,{rec['scan_compile_s']},"
          f"scan program (loop step: {rec['python_loop_compile_s']})")
    return rec


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv, smoke="--smoke" in sys.argv)
