"""Ablation (beyond paper): is the ADAPTIVE schedule alpha_t = a0 + eta*t
actually needed, or would a fixed mixing weight do?

The paper motivates the schedule (§IV-A: small alpha early = fast
convergence, large alpha late = stability) but never isolates it. We run
fixed alpha in {0.1, 0.5, 0.8} vs the paper's schedule under the same
non-iid + limited-device environment.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def run(rounds=60):
    model = build_model(ARCHS["paper-cnn"])
    train, test = make_image_classification(n_train=1500, n_test=400, seed=0)
    clients = build_clients(train, shard_partition(train["label"], 20, seed=0))
    settings = [
        ("adaptive (paper)", dict(alpha0=0.1, eta=2.5e-3)),
        ("fixed a=0.1", dict(alpha0=0.1, eta=0.0)),
        ("fixed a=0.5", dict(alpha0=0.5, eta=0.0)),
        ("fixed a=0.8", dict(alpha0=0.8, eta=0.0)),
    ]
    results = []
    for name, kw in settings:
        fl = FLConfig(num_clients=20, clients_per_round=5, local_epochs=2,
                      local_batch_size=25, lr=0.1, p_limited=0.5,
                      algorithm="ama_fes", seed=0, **kw)
        sim = FederatedSimulation(model, fl, clients, test)
        hist = sim.run(rounds=rounds)
        rec = {"setting": name,
               "acc_at_20": float(np.mean(hist.test_acc[15:20])),
               "accuracy": float(np.mean(hist.test_acc[-10:])),
               "stability_var": hist.stability_variance(20)}
        results.append(rec)
        print(f"ablation,{name},acc20={rec['acc_at_20']:.3f},"
              f"acc={rec['accuracy']:.4f},var={rec['stability_var']:.2f}")
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "ablation_alpha.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    run()
