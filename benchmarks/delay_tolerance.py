"""Delay-tolerance sweep: accuracy vs max_delay per channel model.

Reproduces the paper's Fig. 3 headline — async AMA tolerates up to 15
rounds of staleness with < 1% degradation — and extends it across the
environment registry: the same sweep under i.i.d. Bernoulli delays,
bursty Gilbert-Elliott fading, bandwidth/deadline delays and the
synthetic mobility trace. Emits one accuracy-vs-max_delay table per
environment plus a fused-scan consumption check proving
``make_train_loop`` runs unmodified against every environment's
``batch()`` output.

    PYTHONPATH=src python benchmarks/delay_tolerance.py --smoke   # CI-sized
    PYTHONPATH=src python benchmarks/delay_tolerance.py           # full sweep
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro import env as env_mod
from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.round import as_scan_scheds, init_state, make_train_loop
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")

ENVS = ["bernoulli", "gilbert_elliott", "bandwidth", "trace"]


def scan_check() -> dict[str, float]:
    """Every environment's batch() drives the fused lax.scan engine
    unchanged (same model, same compiled round body)."""
    import jax.numpy as jnp

    cfg = ARCHS["paper-cnn"]
    model = build_model(cfg)
    C, steps, b, rounds = 2, 1, 4, 2
    rng = np.random.RandomState(0)
    batch = {"image": jnp.asarray(rng.randn(C, steps, b, 28, 28, 1),
                                  jnp.float32),
             "label": jnp.asarray(rng.randint(0, 10, (C, steps, b)),
                                  jnp.int32)}
    out = {}
    for name in ENVS:
        fl = FLConfig(num_clients=C, clients_per_round=C, env=name,
                      p_delay=0.5, max_delay=5, lr=0.1, cohorts=C,
                      local_steps=steps, algorithm="ama_fes")
        environment = env_mod.resolve(fl)
        scheds = as_scan_scheds(environment.batch(0, rounds))
        loop = make_train_loop(model, fl, donate=False)
        state = init_state(model, fl, jax.random.PRNGKey(0))
        _, metrics = loop(state, batch, scheds)
        loss = float(np.asarray(metrics["loss"])[-1])
        assert np.isfinite(loss), (name, loss)
        out[name] = loss
        print(f"delay_tolerance.scan_check,{name},loss={loss:.4f}")
    return out


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        max_delays = [0, 5]
        rounds, n_train, n_test, k, m = 6, 320, 160, 8, 4
        epochs, bs = 1, 16
    else:
        max_delays = [0, 5, 10, 15, 20]
        rounds, n_train, n_test, k, m = 60, 1500, 400, 20, 5
        epochs, bs = 2, 25

    model = build_model(ARCHS["paper-cnn"])
    train, test = make_image_classification(n_train=n_train, n_test=n_test,
                                            seed=0)
    clients = build_clients(train, shard_partition(train["label"], k, seed=0))

    results = []
    print("name,env,max_delay,accuracy,stability_var")
    for name in ENVS:
        for md in max_delays:
            fl = FLConfig(num_clients=k, clients_per_round=m,
                          local_epochs=epochs, local_batch_size=bs, lr=0.1,
                          p_limited=0.25, algorithm="ama_fes", env=name,
                          p_delay=0.5, max_delay=md, seed=0)
            sim = FederatedSimulation(model, fl, clients, test)
            hist = sim.run(rounds=rounds)
            last = max(3, rounds // 4)
            rec = {"env": name, "max_delay": md,
                   "accuracy": float(np.mean(hist.test_acc[-last:])),
                   "stability_var": hist.stability_variance(last)}
            results.append(rec)
            print(f"delay_tolerance,{name},{md},{rec['accuracy']:.4f},"
                  f"{rec['stability_var']:.2f}")

    # per-environment tolerance table (the Fig. 3 reading: degradation
    # vs the same environment's zero-delay point)
    head = "".join(f"md={md:<11}" for md in max_delays)
    print(f"\n{'env':<18}{head}")
    for name in ENVS:
        row = [r for r in results if r["env"] == name]
        base = row[0]["accuracy"]
        cells = "".join(
            f"{r['accuracy'] * 100:5.1f}% ({(r['accuracy'] - base) * 100:+5.1f}) "
            for r in row)
        print(f"{name:<18}{cells}")

    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "delay_tolerance.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 2 delay points, 6 rounds, tiny data")
    args = ap.parse_args()
    scan_check()
    run(smoke=args.smoke)
