"""Comm-plane benchmark: compressed uplinks across channel scenarios.

Sweeps plane (none / bf16 / q8 / topk) x scenario (clear /
bandwidth-limited / bursty) through the REAL chunked-scan engine
(``FederatedSimulation`` at the paper-CNN small-world shape) and
records, per combination:

  * ``rounds_per_s``   — engine throughput with the compression and the
    fused dequantize-accumulate server pass in the loop;
  * ``bytes_per_client`` / ``bytes_per_round`` — the EXACT compressed
    payload (``CommPlane.payload_bytes``), the same number the extended
    metrics' ``bytes_on_wire_compressed`` charges;
  * ``final_acc`` and ``acc_delta_vs_dense`` — accuracy against the
    dense plane in the SAME scenario (error feedback should keep the
    delta small at these scales);
  * ``on_time_mean``   — under the bandwidth scenario the deadline
    check consumes the compressed upload size, so compression RAISES
    on-time participation (the paper's Fig. 3 delay tolerance as a
    function of compression level).

Emits ``BENCH_comm_plane.json`` at the repo root with a ``smoke``
section measured at the exact configuration the CI gate re-runs
(``scripts/check_bench.py`` + ``scripts/bench_gates.json``): a
throughput floor (q8 engine speed vs dense, variance-discounted) AND a
bytes-on-wire ceiling — a regression in either direction fails CI.
"""
from __future__ import annotations

import json
import math
import os
import time

import numpy as np

from repro import comm
from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.models.api import build_model
from repro.obs.provenance import provenance

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                   "BENCH_comm_plane.json")

PLANES = ("none", "bf16", "q8", "topk")

#: scenario -> FLConfig overrides (the channel the uplink crosses)
SCENARIOS = {
    # clean Bernoulli participation, no delays: pure engine throughput
    "clear": dict(env="bernoulli", p_delay=0.0, max_delay=0),
    # log-normal uplink rate vs a round deadline: the delay draws
    # consume the ACTUAL compressed upload size (comm.wire_fraction)
    "bandwidth_limited": dict(env="bandwidth", max_delay=5,
                              bw_upload_mbits=16.0, bw_mean_mbps=4.0,
                              bw_sigma=0.8, bw_deadline_s=1.0),
    # Gilbert-Elliott two-state fading bursts
    "bursty": dict(env="gilbert_elliott", p_delay=0.4, max_delay=3),
}

_WORLD = None


def _world():
    global _WORLD
    if _WORLD is None:
        train, test = make_image_classification(n_train=240, n_test=60,
                                                seed=0)
        clients = build_clients(train,
                                shard_partition(train["label"], 8, seed=0))
        model = build_model(ARCHS["paper-cnn"])
        _WORLD = (model, clients, test)
    return _WORLD


def _fl(plane: str, scen: str) -> FLConfig:
    return FLConfig(num_clients=8, clients_per_round=4, local_epochs=1,
                    local_batch_size=10, lr=0.1, p_limited=0.25, seed=0,
                    algorithm="ama_fes", comm_plane=plane,
                    comm_topk_frac=0.05, **SCENARIOS[scen])


def _payloads(fl: FLConfig, params) -> tuple[int, float]:
    """(bytes one client uploads per round, dense/compressed ratio)."""
    dense = comm.dense_bytes(params)
    plane = comm.resolve(fl)
    per_client = plane.payload_bytes(params) if plane else dense
    return per_client, round(dense / max(per_client, 1), 3)


def _measure(plane: str, scen: str, rounds: int) -> dict:
    model, clients, test = _world()
    fl = _fl(plane, scen)
    sim = FederatedSimulation(model, fl, clients, test, use_scan=True)
    # warm pass compiles the exact chunk the timed pass re-dispatches
    # (same chunk length = same program)
    sim.run(rounds=rounds, eval_every=rounds)
    t0 = time.perf_counter()
    hist = sim.run(rounds=rounds, eval_every=rounds)
    dt = time.perf_counter() - t0
    per_client, ratio = _payloads(fl, sim.state["params"])
    m = fl.clients_per_round
    # on-time participation straight from the channel's schedule: the
    # bandwidth env's delay draws consume comm.wire_fraction(fl), so
    # this is where compression buys delay tolerance (paper Fig. 3)
    from repro import env as env_mod
    sb = env_mod.resolve(fl).batch(0, 50)
    on_time = float(np.mean(~np.asarray(sb["delayed"], bool)))
    return {"plane": plane, "scenario": scen,
            "rounds_per_s": round(rounds / dt, 3),
            "final_acc": round(float(hist.test_acc[-1]), 4),
            "bytes_per_client": per_client,
            "bytes_per_round": per_client * m,
            "compression_ratio": ratio,
            "on_time_mean": round(on_time, 3)}


def _sweep(cases, rounds: int) -> list[dict]:
    rows, dense_acc = [], {}
    for plane, scen in cases:
        row = _measure(plane, scen, rounds)
        if plane == "none":
            dense_acc[scen] = row["final_acc"]
        base = dense_acc.get(row["scenario"])
        row["acc_delta_vs_dense"] = (
            round(row["final_acc"] - base, 4) if base is not None else None)
        rows.append(row)
        print(f"comm_plane.{scen}.{plane},{row['rounds_per_s']},rounds/s "
              f"ratio={row['compression_ratio']}x "
              f"bytes/client={row['bytes_per_client']} "
              f"acc_delta={row['acc_delta_vs_dense']}")
    return rows


# the CI gate re-runs the headline pair only: dense vs q8 on the clear
# channel — engine throughput with the fused dequantize-accumulate in
# the loop, plus the (static, exactly reproducible) q8 payload bytes
SMOKE_ROUNDS = 4


def _smoke_rec() -> dict:
    rows = _sweep([("none", "clear"), ("q8", "clear")], SMOKE_ROUNDS)
    dense, q8 = rows[0], rows[1]
    ratio = round(q8["rounds_per_s"] / dense["rounds_per_s"], 3)
    rec = {
        "rows": rows,
        # compressed-engine throughput relative to the dense engine;
        # the 0.8 discount absorbs shared-runner wall-clock jitter so
        # the gate trips on real fusion losses, not noise
        "throughput_ratio": ratio,
        "gate": round(ratio * 0.8, 3),
        # bytes are STATIC per model (q8: one int8 per param + one f32
        # scale per dtype group per cohort) — the 1.05 headroom only
        # covers intentional small model edits; a plane regression that
        # ships dense bytes overshoots it 4x
        "bytes_on_wire": q8["bytes_per_client"],
        "bytes_ceiling": int(math.ceil(q8["bytes_per_client"] * 1.05)),
        "compression_ratio": q8["compression_ratio"],
        "provenance": provenance(),
    }
    print(f"comm_plane.smoke_throughput_ratio,{ratio},q8 over dense")
    print(f"comm_plane.smoke_bytes_on_wire,{rec['bytes_on_wire']},"
          f"ceiling {rec['bytes_ceiling']}")
    return rec


def run(quick: bool = True, smoke: bool = False) -> dict:
    if smoke:
        return _smoke_rec()
    rounds = 6 if quick else 12
    import jax
    rows = _sweep([(p, s) for s in SCENARIOS for p in PLANES], rounds)
    rec = {
        "bench": "comm_plane",
        "backend": jax.default_backend(),
        "rows": rows,
        "smoke": _smoke_rec(),
        "provenance": provenance(),
    }
    with open(OUT, "w") as f:
        json.dump(rec, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(OUT)}")
    return rec


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv, smoke="--smoke" in sys.argv)
