"""Paper-scale end-to-end engine throughput: chunked scan vs per-round loop.

Runs the full §V simulation path (real chunk staging, schedules from the
environment registry, jitted batched eval at the ``eval_every`` cadence)
through both configurations of the unified execution engine — the fused
chunked ``lax.scan`` and the bit-identical per-round-jit fallback — and
reports steady-state rounds/sec. Also measures the telemetry-plane tax:
a third pass with ``fl.extended_metrics`` on and a ``MetricsLogger``
sink (the ``--metrics-out`` configuration) reports
``metrics_overhead`` = metrics-on over metrics-off scan throughput
(the <5% budget the observability acceptance gates on). Emits a
machine-readable ``BENCH_sim_engine.json`` at the repo root so the perf
trajectory of the simulation path is tracked from PR 3 onward.
"""
from __future__ import annotations

import json
import os
import tempfile

from repro.obs.log import MetricsLogger
from repro.obs.provenance import provenance
from repro.obs.timing import sync_time

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                   "BENCH_sim_engine.json")


def _world(n_train: int, n_clients: int, seed: int = 0):
    train, test = make_image_classification(n_train=n_train, n_test=400,
                                            seed=seed)
    clients = build_clients(
        train, shard_partition(train["label"], n_clients, seed=seed))
    return build_model(ARCHS["paper-cnn"]), clients, test


def _timed_pass(sim, rounds: int, eval_every: int) -> tuple[float, float]:
    # obs.timing.sync_time: perf_counter + block_until_ready
    dt, hist = sync_time(sim.run, rounds=rounds, eval_every=eval_every)
    return dt, hist.train_loss[-1]


def _measure(model, fl, clients, test, *, rounds: int, eval_every: int,
             reps: int) -> tuple[dict, dict]:
    """Best-of-``reps`` per mode, modes ALTERNATED pass-by-pass so host
    contention (shared CI/container CPUs) hits both engines alike."""
    sims = {m: FederatedSimulation(model, fl, clients, test,
                                   use_scan=(m == "chunked_scan"))
            for m in ("per_round_loop", "chunked_scan")}
    for sim in sims.values():                    # compile + warm both
        sim.run(rounds=eval_every, eval_every=eval_every)
    best, loss = {m: float("inf") for m in sims}, {}
    for rep in range(reps):
        for m, sim in sims.items():
            dt, tl = _timed_pass(sim, rounds, eval_every)
            best[m] = min(best[m], dt)
            if rep == 0:          # fixed pass: reps don't move the loss
                loss[m] = tl
    out = {}
    for m, sim in sims.items():
        out[m] = {"rounds": rounds, "seconds": round(best[m], 3),
                  "rounds_per_sec": round(rounds / best[m], 3),
                  "per_round_ms": round(best[m] / rounds * 1e3, 2),
                  # loss after warmup + first timed pass; the sim keeps
                  # training across reps (cumulative_rounds in total)
                  "train_loss_after_first_pass": round(loss[m], 4),
                  "cumulative_rounds": sim.t}
    return out["chunked_scan"], out["per_round_loop"]


def _metrics_tax(model, fl, clients, test, *, rounds: int,
                 eval_every: int, reps: int, scan_best: float) -> dict:
    """The telemetry-plane overhead: the SAME chunked-scan pass with
    ``fl.extended_metrics`` on and every row + eval + phase summary
    streamed through a MetricsLogger to a real JSONL file — the
    ``--metrics-out`` configuration end-to-end."""
    with tempfile.NamedTemporaryFile(suffix=".jsonl", delete=False) as f:
        path = f.name
    try:
        logger = MetricsLogger(path)
        sim = FederatedSimulation(model, fl.with_(extended_metrics=True),
                                  clients, test, use_scan=True,
                                  logger=logger)
        sim.run(rounds=eval_every, eval_every=eval_every)   # compile
        best = float("inf")
        for _ in range(reps):
            dt, _ = _timed_pass(sim, rounds, eval_every)
            best = min(best, dt)
        logger.close()
    finally:
        os.unlink(path)
    rps = rounds / best
    return {"rounds": rounds, "seconds": round(best, 3),
            "rounds_per_sec": round(rps, 3),
            # metrics-on throughput over metrics-off (1.0 = free;
            # the observability acceptance budget is >= 0.95)
            "throughput_ratio": round(scan_best / best, 3)}


SMOKE = dict(rounds=4, eval_every=2, reps=2, n_train=400, n_clients=10)


def _bench(*, rounds, eval_every, reps, n_train, n_clients):
    model, clients, test = _world(n_train, n_clients)
    fl = FLConfig(num_clients=n_clients,
                  clients_per_round=max(2, n_clients // 4),
                  local_epochs=2, local_batch_size=25, lr=0.1,
                  algorithm="ama_fes", seed=0)
    scan, loop = _measure(model, fl, clients, test, rounds=rounds,
                          eval_every=eval_every, reps=reps)
    speedup = round(scan["rounds_per_sec"]
                    / max(loop["rounds_per_sec"], 1e-9), 3)
    metrics_on = _metrics_tax(model, fl, clients, test, rounds=rounds,
                              eval_every=eval_every, reps=reps,
                              scan_best=scan["seconds"])
    return fl, scan, loop, speedup, metrics_on


def run(quick: bool = True, smoke: bool = False) -> dict:
    if smoke:
        fl, scan, loop, speedup, metrics_on = _bench(**SMOKE)
        rec = {"chunked_scan": scan, "per_round_loop": loop,
               "speedup": speedup, "gate": round(speedup * 0.8, 3),
               "metrics_on": metrics_on,
               "provenance": provenance()}
        print(f"sim_engine.loop_rounds_per_sec,"
              f"{loop['rounds_per_sec']},")
        print(f"sim_engine.scan_rounds_per_sec,"
              f"{scan['rounds_per_sec']},")
        print(f"sim_engine.speedup,{speedup},x chunked scan over "
              f"per-round loop (smoke)")
        print(f"sim_engine.metrics_throughput_ratio,"
              f"{metrics_on['throughput_ratio']},metrics-on over "
              f"metrics-off scan (smoke)")
        return rec

    rounds, eval_every = (8 if quick else 24), 4
    fl, scan, loop, speedup, metrics_on = _bench(
        rounds=rounds, eval_every=eval_every, reps=3, n_train=1500,
        n_clients=20)
    rec = {"bench": "sim_engine", "scale": "paper",
           "arch": "paper-cnn", "algorithm": fl.algorithm,
           "n_train": 1500, "n_clients": 20,
           "clients_per_round": fl.clients_per_round,
           "eval_every": eval_every,
           "chunked_scan": scan, "per_round_loop": loop,
           "speedup": speedup, "metrics_on": metrics_on,
           "provenance": provenance()}
    print(f"sim_engine.loop_rounds_per_sec,{loop['rounds_per_sec']},")
    print(f"sim_engine.scan_rounds_per_sec,{scan['rounds_per_sec']},")
    print(f"sim_engine.speedup,{rec['speedup']},x chunked scan over "
          f"per-round loop ({rounds} rounds, eval_every={eval_every})")
    print(f"sim_engine.metrics_throughput_ratio,"
          f"{metrics_on['throughput_ratio']},metrics-on over "
          f"metrics-off scan (--metrics-out tax; budget >= 0.95)")
    # CI regression-gate baseline: the exact configuration the smoke
    # gate re-runs (scripts/check_bench.py), variance-discounted so the
    # gate trips on engine regressions, not shared-runner jitter
    _, s_scan, s_loop, s_speedup, _ = _bench(**SMOKE)
    rec["smoke"] = {"speedup": s_speedup,
                    "gate": round(s_speedup * 0.8, 3)}
    print(f"sim_engine.smoke_speedup,{s_speedup},gate baseline "
          f"{rec['smoke']['gate']}")
    with open(OUT, "w") as f:
        json.dump(rec, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(OUT)}")
    return rec


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv, smoke="--smoke" in sys.argv)
