"""Paper Fig. 2: AMA-FES vs naive FL vs FedProx under computation
heterogeneity p in {0.25, 0.5, 0.75} — synchronous setting.

Scale note (EXPERIMENTS.md): the container is CPU-only and offline, so we
run a miniaturised but structurally identical setup: synthetic
MNIST/FMNIST-shaped data (two "datasets" = two generator seeds), K=20
clients (paper: 50), m=5/round (paper: 10), strict 2-class shards,
rounds=60 (paper: 200/300), lr=0.1 (paper's 1e-3 needs ~100x more steps
at this scale). Metrics exactly as the paper: converged accuracy and
variance of the last-rounds test accuracy.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def run(rounds=60, n_train=1500, num_clients=20, m=5, quick=False):
    model = build_model(ARCHS["paper-cnn"])
    results = []
    datasets = {"synth-mnist": 0, "synth-fmnist": 100}
    if quick:
        datasets = {"synth-mnist": 0}
        rounds = min(rounds, 25)    # an explicit smaller budget wins
    for dname, dseed in datasets.items():
        train, test = make_image_classification(
            n_train=n_train, n_test=400, seed=dseed)
        clients = build_clients(
            train, shard_partition(train["label"], num_clients, seed=dseed))
        for p in ([0.25, 0.5, 0.75] if not quick else [0.5]):
            for algo in ("ama_fes", "fedavg", "fedprox"):
                fl = FLConfig(num_clients=num_clients, clients_per_round=m,
                              local_epochs=2, local_batch_size=25, lr=0.1,
                              p_limited=p, algorithm=algo, seed=0)
                sim = FederatedSimulation(model, fl, clients, test)
                hist = sim.run(rounds=rounds)
                last = max(10, rounds // 4)
                rec = {
                    "dataset": dname, "p": p, "algorithm": algo,
                    "accuracy": float(np.mean(hist.test_acc[-last:])),
                    "stability_var": hist.stability_variance(last),
                    "final_loss": float(hist.train_loss[-1]),
                }
                results.append(rec)
                print(f"fig2,{dname},p={p},{algo},"
                      f"acc={rec['accuracy']:.4f},var={rec['stability_var']:.2f}")
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "fig2_sync.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    run()
