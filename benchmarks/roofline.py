"""Roofline table: every (arch x shape) baseline on the single-pod mesh.

Combines the deploy dry-run artifacts (memory, true to the runnable
program) with the calibrated costing (FLOPs/bytes/collectives with scan
trip counts restored). Writes experiments/roofline.json + a markdown
table for EXPERIMENTS.md §Roofline.

Term conventions (documented in EXPERIMENTS.md):
  * all terms are per-device seconds: the optimized HLO is the
    per-partition module, so cost_analysis numbers are per chip.
  * memory_s uses HloCostAnalysis "bytes accessed", which assumes no
    fusion/reuse — a structural UPPER BOUND on HBM traffic.
  * collective_s sums result bytes of collective ops / 50 GB/s link.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import json
import time

from repro.configs.registry import pairs

from benchmarks import costing

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def one_pair(arch, shape_name):
    t0 = time.time()
    c = costing.calibrated_cost(arch, shape_name)
    terms = costing.roofline_terms(c)
    mf = costing.model_flops(arch, shape_name)
    hlo_total = c["flops"] * 256
    rec = {
        "arch": arch, "shape": shape_name,
        "flops_per_dev": c["flops"], "bytes_per_dev": c["bytes"],
        "coll_bytes_per_dev": c["coll"],
        "recurrence_flops_per_dev": c.get("recurrence_flops", 0.0),
        **terms,
        "dominant": costing.dominant(terms),
        "model_flops": mf,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "wall_s": round(time.time() - t0, 1),
    }
    return rec


def main(select=None):
    out = []
    for arch, shape_name, skip in pairs():
        if skip:
            out.append({"arch": arch, "shape": shape_name, "skip": True})
            continue
        if select and (arch, shape_name) not in select:
            continue
        try:
            rec = one_pair(arch, shape_name)
            print(f"{arch:24s} {shape_name:12s} dom={rec['dominant']:10s} "
                  f"c/m/x = {rec['compute_s']:8.3f} {rec['memory_s']:8.3f} "
                  f"{rec['collective_s']:8.3f} s  useful={rec['useful_ratio']:.2f}")
        except Exception as e:
            rec = {"arch": arch, "shape": shape_name,
                   "error": f"{type(e).__name__}: {e}"}
            print(f"{arch:24s} {shape_name:12s} ERROR {rec['error'][:150]}")
        out.append(rec)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "roofline.json"), "w") as f:
        json.dump(out, f, indent=1)

    # markdown table
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful (6ND/HLO) |",
             "|---|---|---|---|---|---|---|"]
    for r in out:
        if r.get("skip"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped (see DESIGN.md) | — |")
        elif "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
                f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
                f"{r['dominant']} | {r['useful_ratio']:.2f} |")
    with open(os.path.join(OUT_DIR, "roofline.md"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"\nwrote {len(out)} records")


if __name__ == "__main__":
    main()
