"""Client-plane benchmark: partitioned mixed-cohort FES execution vs the
masked reference, swept over the limited-device ratio.

Measures exactly what the round engine dispatches
(``core.round.make_round_step`` with ``fl.client_plane`` =
"partitioned" vs "masked") at two shapes:

  * ``paper`` — the §V CNN at paper scale (m=10 cohorts); the masked
    plane builds the full conv backward for every cohort and zeroes the
    limited ones, the partitioned plane never traces it for the limited
    group (Eq. 3);
  * ``transformer`` — a reduced transformer pod shape (C cohorts, token
    batches), where the frozen body is the whole block stack.

Rounds are dispatched per round (a 1-round plan: the partition is the
EXACT per-round split — the configuration ``run_round``, the pod
``--no-scan`` loop and mixed-cadence chunks use; under long fused
chunks the partition is chunk-static and the win shrinks toward the
chunk-minimum limited count). Modes are ALTERNATED pass-by-pass
(best-of-``reps``) so host contention hits both planes alike.

Also lowers both programs dry-run and records HLO FLOP counts proving
the limited program DROPS the body backward (strictly below the full
program) instead of masking it.

Emits ``BENCH_client_plane.json`` at the repo root with a ``smoke``
section measured at the exact configuration the CI regression gate
re-runs (``scripts/check_bench.py``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, reduced
from repro.configs.registry import ARCHS
from repro.core.client import make_limited_local_train, make_local_train
from repro.core.round import init_state, make_round_step
from repro.data.pipeline import partition_plan
from repro.data.synth import make_lm_tokens
from repro.models.api import build_model
from repro.obs.provenance import provenance

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                   "BENCH_client_plane.json")

P_SWEEP = (0.0, 0.25, 0.5, 1.0)


def _world(scale: str):
    """(model, fl_base, batch (C, steps, b, ...)) for a benchmark shape."""
    if scale == "paper":
        model = build_model(ARCHS["paper-cnn"])
        C, steps, b = 10, 4, 25
        rng = np.random.RandomState(0)
        batch = {"image": jnp.asarray(
                     rng.randn(C, steps, b, 28, 28, 1), jnp.float32),
                 "label": jnp.asarray(
                     rng.randint(0, 10, (C, steps, b)), jnp.int32)}
    else:  # transformer-like pod shape
        cfg = reduced(ARCHS["minitron-8b"])
        model = build_model(cfg)
        C, steps, b, S = 4, 2, 2, 64
        data = make_lm_tokens(C * steps * b, S + 1, cfg.vocab_size,
                              n_topics=C, seed=0)
        batch = {"tokens": jnp.asarray(
            data["tokens"][:, :S].reshape(C, steps, b, S), jnp.int32)}
    fl = FLConfig(algorithm="ama_fes", lr=0.05)
    return model, fl, batch


def _sched(C: int, p_limited: float, plan: bool):
    """One round's schedule with an EXACT round(p*C) limited count (the
    representative mixed cohort; a 1-round partition plan is exact)."""
    rng = np.random.RandomState(1)
    limited = np.zeros(C, bool)
    limited[rng.permutation(C)[:int(round(p_limited * C))]] = True
    sched = {"limited": jnp.asarray(limited),
             "delayed": jnp.asarray(np.zeros(C, bool)),
             "delays": jnp.asarray(np.ones(C, np.int32)),
             "data_sizes": jnp.asarray(rng.rand(C) + 0.5, jnp.float32)}
    if plan:
        sched.update({k: jnp.asarray(v[0])
                      for k, v in partition_plan(limited[None]).items()})
    return sched


def _measure(scale: str, p_limited: float, reps: int) -> dict:
    model, fl, batch = _world(scale)
    C = int(jax.tree.leaves(batch)[0].shape[0])
    fns, states, scheds = {}, {}, {}
    for plane in ("masked", "partitioned"):
        flp = fl.with_(client_plane=plane)
        step = make_round_step(model, flp)
        fns[plane] = jax.jit(step)
        states[plane] = init_state(model, flp, jax.random.PRNGKey(0))
        scheds[plane] = _sched(C, p_limited, plan=(plane == "partitioned"))
    best = {plane: float("inf") for plane in fns}
    for plane, fn in fns.items():                # compile + warm
        jax.block_until_ready(fn(states[plane], batch, scheds[plane]))
    for _ in range(reps):                        # alternate passes
        for plane, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(states[plane], batch, scheds[plane]))
            best[plane] = min(best[plane], time.perf_counter() - t0)
    return {"scale": scale, "p_limited": p_limited,
            "masked_ms": round(best["masked"] * 1e3, 2),
            "partitioned_ms": round(best["partitioned"] * 1e3, 2),
            "speedup": round(best["masked"] / best["partitioned"], 3)}


def _flop_counts(scale: str) -> dict:
    """Dry-run HLO FLOPs of the full vs limited (classifier-only)
    program on ONE cohort's batch: the limited program must cost
    strictly less — the body backward is gone, not masked."""
    model, fl, batch = _world(scale)
    b1 = jax.tree.map(lambda x: x[:1], batch)
    params = model.init(jax.random.PRNGKey(0))

    def flops(compiled):
        ca = compiled.cost_analysis()
        return float((ca if isinstance(ca, dict) else ca[0])["flops"])

    full = flops(jax.jit(make_local_train(model, fl)).lower(
        params, b1, jnp.asarray([True])).compile())
    lim = flops(jax.jit(make_limited_local_train(model, fl)).lower(
        params, b1).compile())
    assert 0 < lim < full, (scale, lim, full)
    return {"full_program_flops": full, "limited_program_flops": lim,
            "limited_over_full": round(lim / full, 4)}


def _sweep(cases, reps: int) -> list[dict]:
    rows = []
    for scale, p in cases:
        row = _measure(scale, p, reps)
        rows.append(row)
        print(f"client_plane.{scale}.p{p},{row['speedup']},x partitioned "
              f"over masked ({row['masked_ms']}ms -> "
              f"{row['partitioned_ms']}ms)")
    return rows


# the CI gate re-runs the headline configuration only: the mixed cohort
# at paper scale (p=0.5) — p=0 is parity-by-construction and p=1 is the
# fes_static-shaped corner, both tracked in the committed full sweep
SMOKE_CASES = [("paper", 0.5)]


def run(quick: bool = True, smoke: bool = False) -> dict:
    reps = 3 if (smoke or quick) else 5
    if smoke:
        rows = _sweep(SMOKE_CASES, reps)
        flops = _flop_counts("paper")
        speedup = rows[0]["speedup"]
        # variance-discounted floor for scripts/check_bench.py (~±20%
        # wall-clock jitter on shared runners; the gate catches real
        # plane regressions, not noise)
        rec = {"rows": rows, "speedup": speedup,
               "gate": round(speedup * 0.8, 3), "flops_paper": flops,
               "provenance": provenance()}
        print(f"client_plane.smoke_speedup,{speedup},")
        print(f"client_plane.limited_over_full_flops,"
              f"{flops['limited_over_full']},<1 required")
        return rec

    rows = _sweep([(s, p) for s in ("paper", "transformer")
                   for p in sorted(P_SWEEP)], reps)
    flops = {s: _flop_counts(s) for s in ("paper", "transformer")}
    headline = [r for r in rows
                if r["scale"] == "paper" and r["p_limited"] == 0.5][0]
    smoke_rows = _sweep(SMOKE_CASES, 3)
    s_speedup = smoke_rows[0]["speedup"]
    rec = {
        "bench": "client_plane",
        "backend": jax.default_backend(),
        "rows": rows,
        "flops": flops,
        "headline": {"scale": "paper", "p_limited": 0.5,
                     "speedup": headline["speedup"]},
        "smoke": {"rows": smoke_rows, "speedup": s_speedup,
                  "gate": round(s_speedup * 0.8, 3)},
        "provenance": provenance(),
    }
    for s, f in flops.items():
        print(f"client_plane.{s}.limited_over_full_flops,"
              f"{f['limited_over_full']},body backward dropped")
    print(f"client_plane.headline,{headline['speedup']},x partitioned "
          f"over masked at paper scale p_limited=0.5")
    with open(OUT, "w") as f:
        json.dump(rec, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(OUT)}")
    return rec


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv, smoke="--smoke" in sys.argv)
