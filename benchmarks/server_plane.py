"""Server-plane fusion benchmark: ONE fused pass per round vs the
unfused per-leaf jnp chain, swept over (K, N) up to LLM-scale parameter
counts via flat-param synthesis.

Measures exactly what the round engine dispatches
(``ServerStrategy.fused_server_update`` with ``fl.server_plane`` =
"fused" vs "legacy") for the three server planes:

  * ``mix``   — sync AMA (the paper's Eq. 5 hot loop),
  * ``async`` — async AMA with the staleness ring buffer (Eqs. 6-11),
  * ``adam``  — FedOpt server-Adam on the aggregated pseudo-gradient.

Two synthesis shapes per (K, N):

  * ``flat``  — params as one (N,) vector: the pure bandwidth story and
    the layout a production pod stages params in (one kernel tile grid,
    no flatten cost);
  * ``tree``  — params as a transformer-like multi-leaf pytree summing
    to N: what the engine actually sees at paper/pod scale today. The
    unfused chain pays per-leaf dispatch; the fused path pays the
    flatten/unflatten staging and wins anyway.

Modes are ALTERNATED pass-by-pass (best-of-``reps``) so host contention
hits both engines alike. Emits ``BENCH_server_plane.json`` at the repo
root with a ``smoke`` section measured at the exact sizes the CI
regression gate re-runs (``scripts/check_bench.py``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import strategies
from repro.obs.provenance import provenance

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                   "BENCH_server_plane.json")

MODES = {"mix": ("ama", 0), "async": ("async_ama", 5), "adam": ("fedopt", 0)}

# a transformer-ish leaf split (fractions of N): embedding, per-block
# attention/mlp weights, norms, head — the unfused chain runs per leaf
TREE_FRACS = ([0.18] + [0.035, 0.105, 0.0005, 0.0005] * 4 + [0.02, 0.2])


def _synth_params(rng, N: int, K: int, shape: str):
    """(prev, stacked) as {"flat": ...} or a multi-leaf tree of total N."""
    if shape == "flat":
        sizes = {"p": N}
    else:
        sizes, rem = {}, N
        for i, f in enumerate(TREE_FRACS[:-1]):
            n = max(1, int(N * f))
            sizes[f"l{i:02d}"] = n
            rem -= n
        sizes["head"] = max(1, rem)
    prev = {k: jnp.asarray(rng.randn(n), jnp.float32)
            for k, n in sizes.items()}
    stacked = {k: jnp.asarray(rng.randn(K, n).astype(np.float32))
               for k, n in sizes.items()}
    return prev, stacked


def _sched(rng, K: int, md: int):
    delayed = rng.rand(K) < (0.4 if md else 0.0)
    delays = np.where(delayed, rng.randint(1, max(md, 1) + 1, K), 1)
    return {"limited": jnp.asarray(rng.rand(K) < 0.3),
            "delayed": jnp.asarray(delayed),
            "delays": jnp.asarray(delays.astype(np.int32)),
            "data_sizes": jnp.asarray(rng.rand(K) + 0.5, jnp.float32)}


def _measure(mode: str, K: int, N: int, shape: str, reps: int) -> dict:
    algo, md = MODES[mode]
    rng = np.random.RandomState(0)
    prev, stacked = _synth_params(rng, N, K, shape)
    sched = _sched(rng, K, md)
    fns, auxes = {}, {}
    for impl in ("fused", "legacy"):
        fl = FLConfig(algorithm=algo, max_delay=md,
                      p_delay=0.4 if md else 0.0, server_plane=impl)
        s = strategies.resolve(fl)
        auxes[impl] = s.init_state(prev)
        fns[impl] = jax.jit(
            lambda t, p, c, a, _s=s: _s.fused_server_update(t, p, c,
                                                            sched, a))
    best = {impl: float("inf") for impl in fns}
    for impl, fn in fns.items():                     # compile + warm
        jax.block_until_ready(fn(3, prev, stacked, auxes[impl]))
    for _ in range(reps):                            # alternate passes
        for impl, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(3, prev, stacked, auxes[impl]))
            best[impl] = min(best[impl], time.perf_counter() - t0)
    return {"mode": mode, "shape": shape, "K": K, "N": N,
            "fused_ms": round(best["fused"] * 1e3, 2),
            "unfused_ms": round(best["legacy"] * 1e3, 2),
            "speedup": round(best["legacy"] / best["fused"], 3)}


def _interpret_parity() -> float:
    """Max |err| of the interpret-mode Pallas kernel bodies vs the flat
    oracle AT THE SAME flat layout (the bit-exactness contract; see
    kernels/server_plane.py) — proves the kernel bodies themselves run.
    The CPU perf path above is the jitted oracle; the interpreter is
    emulation."""
    from repro.kernels import ref as kref
    from repro.kernels import server_plane as sp
    rng = np.random.RandomState(1)
    K, N, Q = 4, 4096 + 17, 6
    prev = jnp.asarray(rng.randn(N), jnp.float32)
    stacked = jnp.asarray(rng.randn(K, N).astype(np.float32))
    sizes = jnp.asarray(rng.rand(K) + 0.5, jnp.float32)
    keep = jnp.asarray((rng.rand(K) < 0.7).astype(np.float32))
    delayed = 1.0 - keep                 # async: on-time == kept
    coefs = jnp.asarray([0.1, 2.5e-3, 0.95, 7.0], jnp.float32)
    qsum = jnp.asarray(rng.randn(Q, N).astype(np.float32))
    qgamma = jnp.asarray(rng.rand(Q), jnp.float32)
    delays = jnp.asarray(rng.randint(1, Q, K), jnp.int32)
    tq = jnp.asarray([7, 7 % Q], jnp.int32)
    hyp = jnp.asarray([0.1, 2.5e-3, 0.95, 0.6], jnp.float32)
    m = jnp.asarray(rng.randn(N).astype(np.float32))
    v = jnp.abs(jnp.asarray(rng.randn(N).astype(np.float32)))
    scalars = jnp.asarray([0.9, 0.99, 0.1, 1e-3, 3.0], jnp.float32)
    pairs = [
        (sp.server_mix_flat(prev, stacked, sizes, keep, coefs,
                            block=1024, interpret=True),
         jax.jit(kref.server_mix_math)(prev, stacked, sizes, keep, coefs)),
        (sp.server_async_flat(prev, stacked, qsum, qgamma, sizes,
                              delayed, delays, tq, hyp, block=1024,
                              interpret=True),
         jax.jit(kref.server_async_math)(prev, stacked, qsum, qgamma,
                                         sizes, delayed, delays, tq,
                                         hyp)),
        (sp.server_adam_flat(prev, stacked, m, v, sizes, keep, scalars,
                             block=1024, interpret=True),
         jax.jit(kref.server_adam_math)(prev, stacked, m, v, sizes, keep,
                                        scalars)),
    ]
    err = 0.0
    for got, want in pairs:
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            err = max(err, float(jnp.max(jnp.abs(a - b))))
    return err


def _geomean(xs) -> float:
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-9)))))


def _sweep(cases, reps: int) -> list[dict]:
    rows = []
    for mode, K, N, shape in cases:
        row = _measure(mode, K, N, shape, reps)
        rows.append(row)
        print(f"server_plane.{mode}.{shape}.K{K}.N{N},"
              f"{row['speedup']},x fused over unfused "
              f"({row['unfused_ms']}ms -> {row['fused_ms']}ms)")
    return rows


# smoke rows lean on the mix plane at >=1M params: small-N rows are
# dispatch-dominated and too noisy to gate CI on (the async/adam planes
# are CPU-parity by design — regressions there show up in the committed
# full sweep, not the smoke gate)
SMOKE_CASES = [("mix", 8, 1 << 20, "flat"), ("mix", 8, 1 << 20, "tree"),
               ("async", 8, 1 << 20, "flat")]
FULL_CASES = (
    [(m, 4, 1 << 20, "flat") for m in MODES]
    + [(m, 10, 1 << 22, "flat") for m in MODES]
    + [(m, 10, 1 << 22, "tree") for m in MODES]
    + [(m, 10, 1 << 24, "flat") for m in MODES]
    # largest (K, N): 16 clients x 33.5M params (~2.1 GB of stacked
    # deltas/round) on the paper's primary server plane, the AMA mix —
    # the async ring/server-Adam planes are CPU-parity (their extra
    # (Q, N)/moment streams bound both impls alike; the fusion win
    # there is the TPU VMEM staging) and are reported at 2^24 above
    + [("mix", 16, 1 << 25, "flat")]
)


def run(quick: bool = True, smoke: bool = False) -> dict:
    reps = 3 if smoke else (3 if quick else 5)
    if smoke:
        rows = _sweep(SMOKE_CASES, reps)
        g = _geomean([r["speedup"] for r in rows])
        # "gate" is the variance-discounted floor the CI regression gate
        # compares against (scripts/check_bench.py): shared-runner noise
        # on these wall-clock ratios is ~±20%, so the gate catches real
        # fusion regressions (2-10x drops) without flaking on jitter
        rec = {"rows": rows, "geomean_speedup": round(g, 3),
               "gate": round(g * 0.8, 3), "provenance": provenance()}
        print(f"server_plane.smoke_geomean,{rec['geomean_speedup']},")
        return rec

    rows = _sweep(FULL_CASES, reps)
    largest_n = max(r["N"] for r in rows)
    largest = [r for r in rows if r["N"] == largest_n]
    err = _interpret_parity()
    smoke_rows = _sweep(SMOKE_CASES, 3)
    sg = _geomean([r["speedup"] for r in smoke_rows])
    rec = {
        "bench": "server_plane",
        "backend": jax.default_backend(),
        "rows": rows,
        "largest": {"K": largest[0]["K"], "N": largest_n,
                    "speedups": {r["mode"]: r["speedup"] for r in largest},
                    "min_speedup": min(r["speedup"] for r in largest)},
        "interpret_parity_maxerr": err,
        "smoke": {"rows": smoke_rows, "geomean_speedup": round(sg, 3),
                  "gate": round(sg * 0.8, 3)},
        "provenance": provenance(),
    }
    print(f"server_plane.largest_min_speedup,"
          f"{rec['largest']['min_speedup']},x at K={largest[0]['K']} "
          f"N={largest_n}")
    print(f"server_plane.interpret_parity_maxerr,{err},<=1e-6 expected "
          f"(1-2 ulp: shape-dependent FMA contraction)")
    assert err <= 1e-6, f"interpret kernels diverge from the oracle: {err}"
    with open(OUT, "w") as f:
        json.dump(rec, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(OUT)}")
    return rec


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv, smoke="--smoke" in sys.argv)
