"""Calibrated roofline costing (no real hardware).

``compiled.cost_analysis()`` visits every lax.scan body ONCE, so the
deploy lowering understates FLOPs by the trip counts. This module lowers
small-depth *unrolled* costing variants and extrapolates affinely in the
layer counts (per family), multiplies by the local-steps trip count for
train shapes, and adds an analytic correction for the SSM time-recurrence
(whose chunk scan stays rolled even in costing variants).

All numbers are PER DEVICE (the optimized HLO is the per-partition
module). Validated against MODEL_FLOPS = 6*N*D in the roofline report.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))


import jax

from repro.configs.base import SHAPES, FLConfig
from repro.configs.registry import get_arch, serving_config
from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh
from repro.utils.hlo import collective_stats

# ---------------------------------------------------------------- consts ---
PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)


def _measure(arch, shape_name, mesh, fl, overrides):
    """One costing lowering. For train shapes: ONE local step at the
    production per-step microbatch (global batch scaled by 1/steps);
    callers multiply the result back by the steps trip count."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        from dataclasses import replace as _replace
        prod_steps = fl.local_steps
        cal_shape = _replace(shape,
                             global_batch=shape.global_batch // prod_steps)
        cal_fl = FLConfig(**{**fl.__dict__, "local_steps": 1})
        cfg = get_arch(arch).with_(**overrides)
        low = dryrun.train_lowering(cfg, cal_shape, mesh, cal_fl)
    else:
        low = dryrun.build_lowering(arch, shape_name, mesh, fl,
                                    cfg_overrides=overrides)
    comp = low.compile()
    cost = comp.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_stats(comp.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll.total_bytes)}


def _affine(lo, hi, d_lo, d_hi, target):
    """Extrapolate F(target) from F(d_lo), F(d_hi) affine in depth."""
    slope = {k: (hi[k] - lo[k]) / (d_hi - d_lo) for k in lo}
    return {k: lo[k] + slope[k] * (target - d_lo) for k in lo}, slope


def _recurrence_flops_per_device(cfg, shape, fl, mesh_devices=256):
    """Analytic FLOPs of the SSM/RWKV time recurrence (chunk scans stay
    rolled in the costing lowerings -> counted ~once; add the real count).

    Per token per layer (fwd): rwkv6 ~6*d*hd; mamba2 ~7*(2d)*N.
    Train multiplies by 3 (fwd+bwd) and layers include tail.
    """
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    if shape.kind == "decode":
        return 0.0                    # single step, fully counted
    tokens = shape.global_batch * shape.seq_len
    if cfg.family == "ssm":
        hd = 64
        per_tok_layer = 6 * cfg.d_model * hd
    else:
        per_tok_layer = 7 * (2 * cfg.d_model) * cfg.ssm_state
    mult = 3.0 if shape.kind == "train" else 1.0
    total = tokens * cfg.num_layers * per_tok_layer * mult
    return total / mesh_devices


def calibrated_cost(arch: str, shape_name: str, *, fl: FLConfig = None,
                    verbose: bool = False) -> dict:
    """Per-device {flops, bytes, coll} for the full-depth program."""
    mesh = make_production_mesh()
    shape = SHAPES[shape_name]
    cfg = get_arch(arch) if shape.kind == "train" else serving_config(arch)
    fl = fl or dryrun.fl_for(arch)
    over = {"unroll_layers": True, "unroll_chunks": True}
    steps = fl.local_steps if shape.kind == "train" else 1
    L = cfg.num_layers

    if cfg.family == "audio":
        f11 = _measure(arch, shape_name, mesh, fl,
                       {**over, "encoder_layers": 2, "num_layers": 2,
                        "fes_tail_layers": 1})
        f21 = _measure(arch, shape_name, mesh, fl,
                       {**over, "encoder_layers": 4, "num_layers": 2,
                        "fes_tail_layers": 1})
        f12 = _measure(arch, shape_name, mesh, fl,
                       {**over, "encoder_layers": 2, "num_layers": 4,
                        "fes_tail_layers": 1})
        fe = {k: (f21[k] - f11[k]) / 2 for k in f11}
        fd = {k: (f12[k] - f11[k]) / 2 for k in f11}
        out = {k: f11[k] + (cfg.encoder_layers - 2) * fe[k]
               + (L - 2) * fd[k] for k in f11}
    elif cfg.family == "hybrid" and cfg.attn_every:
        per = cfg.attn_every
        base_L = per + 2              # body=per (1 site), tail=2
        f_a = _measure(arch, shape_name, mesh, fl,
                       {**over, "num_layers": base_L})
        f_b = _measure(arch, shape_name, mesh, fl,
                       {**over, "num_layers": base_L + 1})
        f_c = _measure(arch, shape_name, mesh, fl,
                       {**over, "num_layers": base_L + per})
        fm = {k: f_b[k] - f_a[k] for k in f_a}                 # +1 mamba
        fs = {k: f_c[k] - f_a[k] - per * fm[k] for k in f_a}   # +1 site
        n_sites = (L - cfg.fes_tail_layers) // per
        out = {k: f_a[k] + (L - base_L) * fm[k] + (n_sites - 1) * fs[k]
               for k in f_a}
    else:
        f2 = _measure(arch, shape_name, mesh, fl,
                      {**over, "num_layers": 2, "fes_tail_layers": 1})
        f4 = _measure(arch, shape_name, mesh, fl,
                      {**over, "num_layers": 4, "fes_tail_layers": 1})
        out, _ = _affine(f2, f4, 2, 4, L)

    out = {k: v * steps for k, v in out.items()}
    rec = _recurrence_flops_per_device(cfg, shape, fl)
    out["flops"] += rec
    out["recurrence_flops"] = rec
    if verbose:
        print(f"  calibrated {arch} x {shape_name}: "
              f"flops={out['flops']:.3e}/dev coll={out['coll']:.3e}B/dev")
    return out


def model_flops(arch: str, shape_name: str, fl: FLConfig = None) -> float:
    """Global MODEL_FLOPS = 6*N(active)*D (train: x1 fwd+bwd convention
    6ND; prefill/decode: 2*N*D)."""
    import numpy as np
    from repro.models.api import build_model
    shape = SHAPES[shape_name]
    cfg = get_arch(arch) if shape.kind == "train" else serving_config(arch)
    model = build_model(cfg)
    params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def leaf_count(tree):
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))

    n_total = leaf_count(params_like)
    if cfg.num_experts:
        # active params: experts contribute top_k/E of their weight
        def expert_leaves(tree):
            flat = jax.tree_util.tree_leaves_with_path(tree)
            e = 0
            for path, leaf in flat:
                if "moe" in str(path):
                    e += int(np.prod(leaf.shape))
            return e
        n_exp = expert_leaves(params_like)
        # router counted fully; experts scaled
        n_active = n_total - n_exp + n_exp * cfg.top_k / cfg.num_experts
    else:
        n_active = n_total
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * n_active * D
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * n_active * D
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


def roofline_terms(per_dev: dict) -> dict:
    return {
        "compute_s": per_dev["flops"] / PEAK_FLOPS,
        "memory_s": per_dev["bytes"] / HBM_BW,
        "collective_s": per_dev["coll"] / LINK_BW,
    }


def dominant(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k]).replace("_s", "")
