"""Serving-plane benchmark: paged continuous batching + jitted chunked
prefill vs the seed per-token decode loop.

Sweeps prompt-length MIXTURES x batch sizes — the workload the paged
plane exists for: variable-length prompts stop paying one jit dispatch
per prompt token (chunked prefill) and stop paying max-shape padding
(per-request block tables), while finished requests hand their slots to
queued ones between decode steps (continuous batching).

Both engines serve the IDENTICAL request set and produce the identical
greedy tokens (the bit-identity contract, gated in
tests/test_serve_plane.py); the ratio is pure serving-plane efficiency.
Modes are ALTERNATED pass-by-pass (best-of-``reps``) so host contention
hits both engines alike. Reported per mixture: tokens/sec for both
engines, the speedup, and the paged engine's p50/p95/p99 per-request
latency.

Emits ``BENCH_serve_plane.json`` at the repo root with a ``smoke``
section measured at the exact configuration the CI regression gate
re-runs (``scripts/check_bench.py``).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import serving_config
from repro.models.api import build_model
from repro.obs.provenance import provenance
from repro.serve import LoopEngine, PagedEngine, Request

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                   "BENCH_serve_plane.json")

#: (name, mixture "LENxCOUNT,...", max_new, max_slots, prefill_chunk) —
#: mixtures chosen so prompts dominate (where chunked prefill pays) and
#: so requests outnumber slots (where continuous batching pays)
CASES = [
    ("uniform_short", "8x4", 8, 4, 8),
    ("mixed", "8x4,24x2", 8, 4, 8),
    # 96-token prompts wrap the reduced arch's 64-slot sliding-window
    # ring during prefill — the per-query old/new slot selection path
    ("long_tail", "16x4,96x2", 8, 4, 32),
    ("oversubscribed", "12x8", 8, 4, 8),
]


def _requests(mix: str, max_new: int, vocab: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    reqs, rid = [], 0
    for part in mix.split(","):
        ln, cnt = (int(v) for v in part.split("x"))
        for _ in range(cnt):
            reqs.append(Request(
                rid=rid, max_new=max_new,
                prompt=rng.randint(1, vocab, (ln,)).tolist()))
            rid += 1
    return reqs


def _measure(model, params, case, reps: int) -> dict:
    name, mix, max_new, slots, chunk = case
    vocab = model.cfg.vocab_size
    engines = {
        # the seed serving path: one jit dispatch per token, lockstep
        "loop": LoopEngine(model, params, prefill_chunk=0),
        "paged": PagedEngine(model, params, max_slots=slots, block_size=8,
                             max_batch_tokens=0, prefill_chunk=chunk),
    }
    for eng in engines.values():                       # compile + warm
        eng.run(_requests(mix, max_new, vocab))
    best = {k: None for k in engines}
    for _ in range(reps):                              # alternate passes
        for k, eng in engines.items():
            eng.run(_requests(mix, max_new, vocab))
            s = eng.last_summary
            if best[k] is None or s["wall_s"] < best[k]["wall_s"]:
                best[k] = s
    return {
        "case": name, "mixture": mix, "max_new": max_new,
        "max_slots": slots,
        "loop_tokens_per_s": best["loop"]["tokens_per_s"],
        "paged_tokens_per_s": best["paged"]["tokens_per_s"],
        "speedup": round(best["paged"]["tokens_per_s"]
                         / best["loop"]["tokens_per_s"], 3),
        "paged_latency": {k: best["paged"][k]
                          for k in ("p50_ms", "p95_ms", "p99_ms")},
        "loop_latency": {k: best["loop"][k]
                         for k in ("p50_ms", "p95_ms", "p99_ms")},
    }


def _sweep(model, params, cases, reps: int) -> list[dict]:
    rows = []
    for case in cases:
        row = _measure(model, params, case, reps)
        rows.append(row)
        print(f"serve_plane.{row['case']},{row['speedup']},x paged over "
              f"per-token loop ({row['loop_tokens_per_s']} -> "
              f"{row['paged_tokens_per_s']} tok/s, "
              f"p95 {row['paged_latency']['p95_ms']}ms)")
    return rows


# the CI gate re-runs the headline mixture only: mixed 16/96-token
# prompts with requests > slots — chunked prefill, per-request block
# tables and continuous batching all in play
SMOKE_CASES = [("long_tail", "16x4,96x2", 8, 4, 32)]


def run(quick: bool = True, smoke: bool = False) -> dict:
    cfg = reduced(serving_config("minitron-8b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reps = 2 if (smoke or quick) else 4

    if smoke:
        rows = _sweep(model, params, SMOKE_CASES, reps)
        speedup = rows[0]["speedup"]
        # variance-discounted floor for scripts/check_bench.py (~±20%
        # wall-clock jitter on shared runners)
        rec = {"rows": rows, "speedup": speedup,
               "gate": round(speedup * 0.8, 3), "provenance": provenance()}
        print(f"serve_plane.smoke_speedup,{speedup},")
        return rec

    rows = _sweep(model, params, CASES, reps)
    geo = float(np.exp(np.mean([np.log(r["speedup"]) for r in rows])))
    smoke_rows = _sweep(model, params, SMOKE_CASES, 2)
    s_speedup = smoke_rows[0]["speedup"]
    rec = {
        "bench": "serve_plane",
        "backend": jax.default_backend(),
        "arch": "minitron-8b (reduced serving config)",
        "rows": rows,
        "geomean_speedup": round(geo, 3),
        "smoke": {"rows": smoke_rows, "speedup": s_speedup,
                  "gate": round(s_speedup * 0.8, 3)},
        "provenance": provenance(),
    }
    print(f"serve_plane.geomean,{rec['geomean_speedup']},x paged over "
          f"per-token loop across mixtures")
    with open(OUT, "w") as f:
        json.dump(rec, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(OUT)}")
    return rec


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv, smoke="--smoke" in sys.argv)
