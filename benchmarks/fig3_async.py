"""Paper Fig. 3: asynchronous AMA under transmission delay.

Moderate (p_delay=0.3) and severe (0.7) environments, max delay
{5, 10, 15} rounds; the paper's claim: under moderate delay the accuracy
degradation up to 15 rounds of staleness is < 1%.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def run(rounds=60, quick=False):
    model = build_model(ARCHS["paper-cnn"])
    train, test = make_image_classification(n_train=1500, n_test=400, seed=0)
    clients = build_clients(train, shard_partition(train["label"], 20, seed=0))
    results = []
    grids = [("none", 0.0, 0)]
    delays = [5, 15] if quick else [5, 10, 15]
    envs = [("moderate", 0.3)] if quick else [("moderate", 0.3),
                                              ("severe", 0.7)]
    for env, pd in envs:
        for md in delays:
            grids.append((env, pd, md))
    if quick:
        rounds = min(rounds, 25)    # an explicit smaller budget wins
    for env, pd, md in grids:
        fl = FLConfig(num_clients=20, clients_per_round=5, local_epochs=2,
                      local_batch_size=25, lr=0.1, p_limited=0.25,
                      algorithm="ama_fes", p_delay=pd, max_delay=md, seed=0)
        sim = FederatedSimulation(model, fl, clients, test)
        hist = sim.run(rounds=rounds)
        last = max(10, rounds // 4)
        rec = {"env": env, "p_delay": pd, "max_delay": md,
               "accuracy": float(np.mean(hist.test_acc[-last:])),
               "stability_var": hist.stability_variance(last)}
        results.append(rec)
        print(f"fig3,{env},md={md},acc={rec['accuracy']:.4f},"
              f"var={rec['stability_var']:.2f}")
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "fig3_async.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    run()
