"""Kernel microbenchmarks: interpret-mode correctness + CPU timing of the
jnp reference (the TPU timing story lives in the roofline; these numbers
prove the kernels run and give a per-call CSV)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ama_mix import ama_mix_flat
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(quick=False):
    rows = []
    rng = np.random.RandomState(0)

    # ama_mix: server aggregation of K=10 clients over 4M params
    N, K = (1 << 20 if quick else 1 << 22), 10
    prev = jnp.asarray(rng.randn(N), jnp.float32)
    stacked = jnp.asarray(rng.randn(K, N), jnp.float32)
    alpha = jnp.float32(0.3)
    w = jnp.asarray(rng.rand(K), jnp.float32)
    ref_fn = jax.jit(lambda p, s, a, ww: ref.ama_mix_ref(p, s, a, ww))
    us = _time(ref_fn, prev, stacked, alpha, w)
    bw = (K + 2) * N * 4 / (us * 1e-6) / 1e9
    rows.append(("ama_mix_ref_cpu", us, f"{bw:.1f}GB/s_eff"))
    got = ama_mix_flat(prev[:65536], stacked[:, :65536], alpha, w,
                       interpret=True)
    want = ref.ama_mix_ref(prev[:65536], stacked[:, :65536], alpha, w)
    err = float(jnp.max(jnp.abs(got - want)))
    rows.append(("ama_mix_pallas_interpret_maxerr", err, "allclose"))

    # flash attention
    B, S, H, hd = 1, (256 if quick else 512), 4, 64
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    ref_attn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time(ref_attn, q, k, v)
    rows.append((f"attention_ref_cpu_S{S}", us, ""))
    got = flash_attention(q, k, v, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_attn(q, k, v))))
    rows.append(("flash_attention_interpret_maxerr", err, "allclose"))

    # rwkv6 scan
    B, S, H, hd = 2, (128 if quick else 512), 4, 64
    r = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.5
    kk = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.5
    vv = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    ww = jnp.asarray(rng.rand(B, S, H, hd) * 0.5 + 0.4, jnp.float32)
    u = jnp.asarray(rng.randn(H, hd) * 0.1, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    ref_scan = jax.jit(lambda *a: ref.rwkv6_scan_ref(*a))
    us = _time(lambda *a: ref_scan(*a)[0], r, kk, vv, ww, u, s0)
    rows.append((f"rwkv6_scan_ref_cpu_S{S}", us, ""))
    y, _ = rwkv6_scan(r, kk, vv, ww, u, s0, chunk=128, interpret=True)
    y2, _ = ref_scan(r, kk, vv, ww, u, s0)
    err = float(jnp.max(jnp.abs(y - y2)))
    rows.append(("rwkv6_scan_interpret_maxerr", err, "allclose"))

    for name, val, extra in rows:
        print(f"kernel,{name},{val},{extra}")
    return rows


if __name__ == "__main__":
    run()
