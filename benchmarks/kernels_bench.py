"""Kernel microbenchmarks: interpret-mode correctness + CPU timing of the
fused oracles (the TPU timing story lives in the roofline; these numbers
prove the kernels run and give a per-call CSV).

The server-side rows go through the SAME entry points the round engine
dispatches (``repro.kernels.server_plane``): the jitted fused oracle for
CPU timing and the interpret-mode Pallas kernels for body validation —
the deep (K, N)-swept fused-vs-unfused comparison is
``benchmarks/server_plane.py``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.server_plane import (server_adam_flat, server_async_flat,
                                        server_mix_flat, _ref_adam,
                                        _ref_async, _ref_mix)


def _time(fn, *args, n=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def _maxerr(got, want) -> float:
    return max(float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32))))
               for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)))


def run(quick=False, smoke=False):
    rows = []
    rng = np.random.RandomState(0)

    # --- server plane: K clients over N flat params, one fused pass ---
    N = 1 << 16 if smoke else (1 << 20 if quick else 1 << 22)
    K, Q = 10, 6
    prev = jnp.asarray(rng.randn(N), jnp.float32)
    stacked = jnp.asarray(rng.randn(K, N).astype(np.float32))
    sizes = jnp.asarray(rng.rand(K) + 0.5, jnp.float32)
    keep = jnp.asarray((rng.rand(K) < 0.7).astype(np.float32))
    delayed = 1.0 - keep                 # async: on-time == kept
    coefs = jnp.asarray([0.1, 2.5e-3, 0.95, 7.0], jnp.float32)
    qsum = jnp.asarray(rng.randn(Q, N).astype(np.float32))
    qgamma = jnp.asarray(rng.rand(Q), jnp.float32)
    delays = jnp.asarray(rng.randint(1, Q, K), jnp.int32)
    tq = jnp.asarray([7, 7 % Q], jnp.int32)
    hyp = jnp.asarray([0.1, 2.5e-3, 0.95, 0.6], jnp.float32)
    m = jnp.asarray(rng.randn(N).astype(np.float32))
    v = jnp.abs(jnp.asarray(rng.randn(N).astype(np.float32)))
    scalars = jnp.asarray([0.9, 0.99, 0.1, 1e-3, 3.0], jnp.float32)

    us = _time(_ref_mix, prev, stacked, sizes, keep, coefs)
    bw = (K + 2) * N * 4 / (us * 1e-6) / 1e9
    rows.append(("server_mix_fused_cpu", us, f"{bw:.1f}GB/s_eff"))
    us = _time(_ref_async, prev, stacked, qsum, qgamma, sizes, delayed,
               delays, tq, hyp)
    rows.append(("server_async_fused_cpu", us, f"K{K}_Q{Q}"))
    us = _time(_ref_adam, prev, stacked, m, v, sizes, keep, scalars)
    rows.append(("server_adam_fused_cpu", us, ""))

    n_val = min(N, 1 << 16)
    sl = lambda x: x[..., :n_val]
    rows.append(("server_mix_interpret_maxerr", _maxerr(
        server_mix_flat(sl(prev), sl(stacked), sizes, keep, coefs,
                        block=8192, interpret=True),
        _ref_mix(sl(prev), sl(stacked), sizes, keep, coefs)), "allclose"))
    rows.append(("server_async_interpret_maxerr", _maxerr(
        server_async_flat(sl(prev), sl(stacked), sl(qsum), qgamma, sizes,
                          delayed, delays, tq, hyp, block=8192,
                          interpret=True),
        _ref_async(sl(prev), sl(stacked), sl(qsum), qgamma, sizes,
                   delayed, delays, tq, hyp)), "allclose"))
    rows.append(("server_adam_interpret_maxerr", _maxerr(
        server_adam_flat(sl(prev), sl(stacked), sl(m), sl(v), sizes, keep,
                         scalars, block=8192, interpret=True),
        _ref_adam(sl(prev), sl(stacked), sl(m), sl(v), sizes, keep,
                  scalars)), "allclose"))

    # --- flash attention ---
    B, S, H, hd = 1, (128 if smoke else 256 if quick else 512), 4, 64
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.3
    vv = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    ref_attn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time(ref_attn, q, k, vv)
    rows.append((f"attention_ref_cpu_S{S}", us, ""))
    got = flash_attention(q, k, vv, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_attn(q, k, vv))))
    rows.append(("flash_attention_interpret_maxerr", err, "allclose"))

    # --- rwkv6 scan ---
    B, S, H, hd = 2, (64 if smoke else 128 if quick else 512), 4, 64
    r = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.5
    kk = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32) * 0.5
    vv = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    ww = jnp.asarray(rng.rand(B, S, H, hd) * 0.5 + 0.4, jnp.float32)
    u = jnp.asarray(rng.randn(H, hd) * 0.1, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    ref_scan = jax.jit(lambda *a: ref.rwkv6_scan_ref(*a))
    us = _time(lambda *a: ref_scan(*a)[0], r, kk, vv, ww, u, s0)
    rows.append((f"rwkv6_scan_ref_cpu_S{S}", us, ""))
    y, _ = rwkv6_scan(r, kk, vv, ww, u, s0, chunk=64, interpret=True)
    y2, _ = ref_scan(r, kk, vv, ww, u, s0)
    err = float(jnp.max(jnp.abs(y - y2)))
    rows.append(("rwkv6_scan_interpret_maxerr", err, "allclose"))

    for name, val, extra in rows:
        print(f"kernel,{name},{val},{extra}")
    for name, val, _ in rows:
        if name.endswith("maxerr"):
            assert val <= 3e-2, (name, val)
    return rows


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv, smoke="--smoke" in sys.argv)
