"""Benchmark entrypoint: one function per paper table/figure + kernels.

``python -m benchmarks.run``          — quick mode (CI-sized)
``python -m benchmarks.run --smoke``  — tiny pass (the CI rot check:
                                        every sub-benchmark must run)
``python -m benchmarks.run --full``   — paper-scale miniatures (slower)

Every sub-benchmark routes through the current registries (server
strategies, environments) and the fused server-plane API — the engine
throughput and server-plane sweeps with committed baselines are
``benchmarks/sim_engine.py`` and ``benchmarks/server_plane.py``
(gated in CI by ``scripts/check_bench.py``). The roofline sweep
(40 pairs, heavy compiles) stays separate:
``python benchmarks/roofline.py``.
"""
from __future__ import annotations

import os
import sys

# runnable both as `python -m benchmarks.run` and `python benchmarks/run.py`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    full = "--full" in sys.argv
    smoke = "--smoke" in sys.argv and not full
    quick = not full
    print("name,value,derived")

    print("# --- Fig.2: sync AMA-FES vs naive FL vs FedProx ---")
    from benchmarks import fig2_sync
    if smoke:
        fig2_sync.run(rounds=2, n_train=240, num_clients=8, m=4, quick=True)
    else:
        fig2_sync.run(quick=quick)

    print("# --- Fig.3: async AMA delay tolerance ---")
    from benchmarks import fig3_async
    fig3_async.run(rounds=2 if smoke else 60, quick=quick)

    print("# --- kernels (incl. fused server plane) ---")
    from benchmarks import kernels_bench
    kernels_bench.run(quick=quick, smoke=smoke)

    print("# --- round engine: fused scan vs per-round jit ---")
    from benchmarks import round_scan
    round_scan.run(quick=quick, smoke=smoke)

    if full:
        print("# --- ablation: adaptive vs fixed alpha ---")
        from benchmarks import ablation_alpha
        ablation_alpha.run()

    print("# done. engine/server-plane sweeps: benchmarks/sim_engine.py, "
          "benchmarks/server_plane.py; roofline: experiments/roofline.md "
          "(python benchmarks/roofline.py)")


if __name__ == "__main__":
    main()
