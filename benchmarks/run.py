"""Benchmark entrypoint: one function per paper table/figure + kernels.

``python -m benchmarks.run``          — quick mode (CI-sized)
``python -m benchmarks.run --full``   — paper-scale miniatures (slower)

The roofline sweep (40 pairs, heavy compiles) is separate:
``python benchmarks/roofline.py``.
"""
from __future__ import annotations

import sys


def main() -> None:
    full = "--full" in sys.argv
    quick = not full
    print("name,value,derived")

    print("# --- Fig.2: sync AMA-FES vs naive FL vs FedProx ---")
    from benchmarks import fig2_sync
    fig2_sync.run(quick=quick)

    print("# --- Fig.3: async AMA delay tolerance ---")
    from benchmarks import fig3_async
    fig3_async.run(quick=quick)

    print("# --- kernels ---")
    from benchmarks import kernels_bench
    kernels_bench.run(quick=quick)

    print("# --- round engine: fused scan vs per-round jit ---")
    from benchmarks import round_scan
    round_scan.run(quick=quick)

    if full:
        print("# --- ablation: adaptive vs fixed alpha ---")
        from benchmarks import ablation_alpha
        ablation_alpha.run()

    print("# done. roofline: experiments/roofline.md "
          "(python benchmarks/roofline.py)")


if __name__ == "__main__":
    main()
