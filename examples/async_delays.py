"""Asynchronous AMA under wireless-style delays (paper §IV-B / Fig. 3).

Shows the staleness-weighted ring buffer absorbing delayed updates —
by default across the paper's no-delay / moderate (30%) / severe (70%)
i.i.d. settings, but any registered environment or named scenario works:

    PYTHONPATH=src python examples/async_delays.py
    PYTHONPATH=src python examples/async_delays.py --env gilbert_elliott
    PYTHONPATH=src python examples/async_delays.py --scenario mobility-trace
"""
import argparse

import numpy as np

from repro import env as env_mod
from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.async_ama import mixing_weights
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.models.api import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="bernoulli", choices=env_mod.names(),
                    help="environment for the delay sweep")
    ap.add_argument("--scenario", default=None,
                    choices=env_mod.scenarios.names(),
                    help="run ONE named scenario instead of the sweep")
    ap.add_argument("--rounds", type=int, default=40)
    args = ap.parse_args()

    fl0 = FLConfig()
    print("staleness-based weights (Eqs. 9-11) at round t=100, three stale "
          "updates with staleness 1, 5, 10:")
    alpha, beta, gammas = mixing_weights(fl0, 100, [1, 5, 10])
    print(f"  alpha={alpha:.4f} beta={beta:.4f} gammas="
          f"{[round(g, 4) for g in gammas]}  (sum={alpha+beta+sum(gammas):.4f})")

    train, test = make_image_classification(n_train=1500, n_test=400, seed=0)
    clients = build_clients(train, shard_partition(train["label"], 20, seed=0))
    model = build_model(ARCHS["paper-cnn"])
    base = FLConfig(num_clients=20, clients_per_round=5, local_epochs=2,
                    local_batch_size=25, lr=0.1, p_limited=0.25,
                    algorithm="ama_fes", seed=0)

    if args.scenario:
        grid = [(args.scenario, env_mod.scenarios.apply(base, args.scenario))]
    elif env_mod.get(args.env).name == "bernoulli":  # aliases included
        # the paper's sweep: delay probability 0 / 30% / 70%, staleness 10
        grid = [(tag, base.with_(env=args.env, p_delay=pd,
                                 max_delay=10 if pd else 0))
                for tag, pd in [("no-delay", 0.0), ("moderate", 0.3),
                                ("severe", 0.7)]]
    else:
        # generic envs own their delay probability; sweep the staleness cap
        grid = [(f"max_delay={md}", base.with_(env=args.env, max_delay=md))
                for md in (0, 5, 15)]

    for tag, fl in grid:
        sim = FederatedSimulation(model, fl, clients, test)
        hist = sim.run(rounds=args.rounds)
        print(f"{tag:15s} [env={fl.env}]: "
              f"accuracy={np.mean(hist.test_acc[-5:]):.3f} "
              f"var={hist.stability_variance(15):.2f}")


if __name__ == "__main__":
    main()
