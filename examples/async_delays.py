"""Asynchronous AMA under wireless-style delays (paper §IV-B / Fig. 3).

Shows the staleness-weighted ring buffer absorbing delayed updates:
moderate (30%) and severe (70%) delay environments, max staleness 10.

    PYTHONPATH=src python examples/async_delays.py
"""
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.async_ama import mixing_weights
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.models.api import build_model


def main():
    fl0 = FLConfig()
    print("staleness-based weights (Eqs. 9-11) at round t=100, three stale "
          "updates with staleness 1, 5, 10:")
    alpha, beta, gammas = mixing_weights(fl0, 100, [1, 5, 10])
    print(f"  alpha={alpha:.4f} beta={beta:.4f} gammas="
          f"{[round(g, 4) for g in gammas]}  (sum={alpha+beta+sum(gammas):.4f})")

    train, test = make_image_classification(n_train=1500, n_test=400, seed=0)
    clients = build_clients(train, shard_partition(train["label"], 20, seed=0))
    model = build_model(ARCHS["paper-cnn"])

    for env, p_delay in [("no-delay", 0.0), ("moderate", 0.3),
                         ("severe", 0.7)]:
        fl = FLConfig(num_clients=20, clients_per_round=5, local_epochs=2,
                      local_batch_size=25, lr=0.1, p_limited=0.25,
                      algorithm="ama_fes", p_delay=p_delay,
                      max_delay=10 if p_delay else 0, seed=0)
        sim = FederatedSimulation(model, fl, clients, test)
        hist = sim.run(rounds=40)
        print(f"{env:9s}: accuracy={np.mean(hist.test_acc[-5:]):.3f} "
              f"var={hist.stability_variance(15):.2f}")


if __name__ == "__main__":
    main()
