"""Quickstart: the paper's experiment end-to-end in ~40 lines.

Trains the paper's 2-conv/3-FC CNN federatedly over 20 non-iid clients
(2-class shards) with AMA aggregation + FES computation reduction, then
compares against naive FedAvg — on the unified chunked-scan execution
engine: each ``eval_every`` chunk of rounds is ONE fused ``lax.scan``
program, batches staged in one gather with the next chunk prefetched
host-side, eval jitted and batched. Runs in ~1 min on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core.simulation import FederatedSimulation
from repro.data.partition import shard_partition
from repro.data.pipeline import build_clients
from repro.data.synth import make_image_classification
from repro.launch.mesh import engine_mesh
from repro.models.api import build_model


def main():
    # 1. data: synthetic MNIST-shaped classification, pathological non-iid
    train, test = make_image_classification(n_train=1500, n_test=400, seed=0)
    partition = shard_partition(train["label"], num_clients=20, seed=0)
    clients = build_clients(train, partition)

    # 2. model: the paper's CNN (Section V)
    model = build_model(ARCHS["paper-cnn"])

    # 3. federated training: AMA-FES vs naive FL, both on the fused
    #    chunked-scan engine under the FL mesh (degenerate on CPU; the
    #    identical program shards the client axis on a pod)
    for algo in ("ama_fes", "fedavg"):
        fl = FLConfig(num_clients=20, clients_per_round=5, local_epochs=2,
                      local_batch_size=25, lr=0.1, p_limited=0.5,
                      algorithm=algo, seed=0)
        sim = FederatedSimulation(model, fl, clients, test,
                                  mesh=engine_mesh(fl.clients_per_round))
        # eval_every=1 keeps one test_acc entry per round (the paper's
        # metric windows); raise it to trade eval cadence for speed —
        # the scan chunk length follows it
        hist = sim.run(rounds=60)
        print(f"{algo:8s}: accuracy={np.mean(hist.test_acc[-5:]):.3f}  "
              f"stability_var={hist.stability_variance(20):.2f}  "
              f"(lower var = more stable)")
        # sim.save("quickstart.npz") would checkpoint {params, t, aux};
        # sim.resume(...) continues bit-identically.


if __name__ == "__main__":
    main()
