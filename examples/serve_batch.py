"""Batched serving of a federated-trained model through the engine API.

Covers three cache families and picks the richest engine each supports:

- minitron (dense GQA, sliding-window ring) -> ``PagedEngine``: paged
  KV pool, jitted chunked prefill, continuous batching
- rwkv6 (recurrent state, no KV cache) -> ``LoopEngine`` per-token
- whisper (cross+self caches) -> ``LoopEngine`` with chunked prefill

Every engine serves the same variable-length request mix and reports
tokens/sec plus per-request latency percentiles.

    PYTHONPATH=src python examples/serve_batch.py [--smoke]
"""
import sys
import time

import jax
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import serving_config
from repro.models.api import build_model
from repro.serve import LoopEngine, PagedEngine, Request


def _requests(vocab: int, lens, max_new: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    return [Request(rid=i, max_new=max_new,
                    prompt=rng.randint(1, vocab, (ln,)).tolist())
            for i, ln in enumerate(lens)]


def _engine_for(model, params, smoke: bool):
    """Richest engine the model family supports (see module doc)."""
    if model.prefill_paged is not None:
        return "paged", PagedEngine(model, params, max_slots=4,
                                    block_size=8, prefill_chunk=8)
    if model.prefill is not None:
        return "loop+prefill", LoopEngine(model, params, prefill_chunk=8)
    return "loop", LoopEngine(model, params)


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    lens = [8, 8, 20, 20] if smoke else [8, 8, 8, 24, 24, 40]
    max_new = 4 if smoke else 12
    for arch in ["minitron-8b", "rwkv6-3b", "whisper-medium"]:
        cfg = reduced(serving_config(arch))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        kind, eng = _engine_for(model, params, smoke)
        t0 = time.time()
        results = eng.run(_requests(cfg.vocab_size, lens, max_new))
        dt = time.time() - t0
        s = eng.last_summary
        assert len(results) == len(lens)
        assert all(r["new_tokens"] == max_new for r in results)
        print(f"{arch:16s} [{kind:12s}]: {len(lens)} reqs x {max_new} "
              f"tokens in {dt:5.2f}s ({s['tokens_per_s']:7.1f} tok/s, "
              f"p95 {s['p95_ms']:.1f}ms)")
    if smoke:
        print("serve_batch.smoke,ok,")


if __name__ == "__main__":
    main()
