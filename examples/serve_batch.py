"""Batched serving of a federated-trained model with a KV cache.

Covers three cache families: dense GQA ring-buffer attention (minitron
SWA variant), RWKV-6 recurrent state, and whisper's cross+self caches.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import serving_config
from repro.launch.serve import batched_decode
from repro.models.api import build_model


def main():
    rng = np.random.RandomState(0)
    for arch in ["minitron-8b", "rwkv6-3b", "whisper-medium"]:
        cfg = reduced(serving_config(arch))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, P, new = 4, 8, 12
        prompts = jnp.asarray(rng.randint(1, cfg.vocab_size, (B, P)),
                              jnp.int32)
        t0 = time.time()
        out = batched_decode(model, params, prompts, new, P + new + 1)
        dt = time.time() - t0
        print(f"{arch:16s}: {B}x{new} tokens in {dt:5.2f}s "
              f"({B * new / dt:6.1f} tok/s CPU), out shape {out.shape}")


if __name__ == "__main__":
    main()
