"""Federated fine-tuning of a transformer LM with the pod-scale round.

A reduced minitron-family decoder trains over 4 client cohorts on
topic-conditioned synthetic token streams (each cohort = one topic:
non-iid in LM form). The same `make_round_step` program runs on a v5e
pod via launch/dryrun.py's mesh machinery.

    PYTHONPATH=src python examples/llm_federated.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, reduced
from repro.configs.registry import ARCHS
from repro.core.round import init_state, make_round_step
from repro.data.synth import make_lm_tokens
from repro.models.api import build_model


def main():
    cfg = reduced(ARCHS["minitron-8b"]).with_(vocab_size=512)
    model = build_model(cfg)
    C, steps, b, S = 4, 8, 4, 64
    fl = FLConfig(cohorts=C, local_steps=steps, algorithm="ama_fes",
                  lr=0.2, p_limited=0.25, max_delay=3, p_delay=0.3,
                  alpha0=0.05, eta=1e-3)

    state = init_state(model, fl, jax.random.PRNGKey(0))
    step = jax.jit(make_round_step(model, fl))
    rng = np.random.RandomState(0)

    data = make_lm_tokens(C * 64, S, 512, n_topics=C, seed=0)
    by_topic = [data["tokens"][data["label"] == c] for c in range(C)]

    print(f"federated LM: {C} cohorts x {steps} steps x batch {b}, "
          f"FES tail={cfg.fes_tail_layers} layers, async max_delay=3")
    for r in range(20):
        batch_np = np.stack([
            t[rng.randint(0, len(t), steps * b)].reshape(steps, b, S)
            for t in by_topic])
        sched = {"limited": jnp.asarray(rng.rand(C) < fl.p_limited),
                 "delayed": jnp.asarray(rng.rand(C) < fl.p_delay),
                 "delays": jnp.asarray(
                     rng.randint(1, fl.max_delay + 1, C), jnp.int32),
                 "data_sizes": jnp.ones((C,), jnp.float32)}
        t0 = time.time()
        state, metrics = step(state, {"tokens": jnp.asarray(batch_np)}, sched)
        print(f"round {r:2d}: loss={float(metrics['loss']):.4f} "
              f"on_time={int(metrics['n_on_time'])}/{C} "
              f"({time.time() - t0:.2f}s)")


if __name__ == "__main__":
    main()
